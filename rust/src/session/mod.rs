//! The unified inference facade: **compile → load → run** behind one typed,
//! weight-persistent API.
//!
//! The paper's pitch is *runtime programmability*: one compiled command
//! stream drives the 8-MVU array at any precision without reconfiguration.
//! [`InferenceSession`] is that idea as an API. A [`SessionBuilder`]
//! compiles the model once, builds the system once, loads the weight,
//! scaler and bias RAMs and the RISC-V program **once**, and then serves
//! [`InferenceSession::run`] repeatedly, resetting only activation state
//! (activation RAMs, CPU registers, DRAM row flags, crossbar FIFOs)
//! between images — the warm-weight hot path measured in
//! `rust/benches/hotpath.rs`.
//!
//! The README Quickstart, as a compiling doctest (`cargo test --doc` keeps
//! it honest):
//!
//! ```no_run
//! use barvinn::codegen::EdgePolicy;
//! use barvinn::model::zoo;
//! use barvinn::session::SessionBuilder;
//! use barvinn::sim::Tensor3;
//!
//! # fn main() -> Result<(), barvinn::session::SessionError> {
//! // build: compile the model and make weights resident (any precision).
//! let model = zoo::resnet9_cifar10(/*abits=*/2, /*wbits=*/2);
//! let mut session = SessionBuilder::new(model)
//!     .edge_policy(EdgePolicy::PadInRam) // or SkipEdges (Table-3-exact)
//!     .fuel(50_000_000)                  // per-run cycle budget
//!     .build()?;                         // Err(SessionError::Compile(..)) on bad models
//!
//! // run: warm per-image hot path.
//! let input = Tensor3::zeros(64, 32, 32);
//! let out = session.run(&input)?;        // Err(FuelExhausted / Fault / Deadlock / Launch)
//! println!("{} MVU cycles, {} system cycles", out.total_mvu_cycles, out.system_cycles);
//!
//! // stream: a batch with up to 8 frames in flight across the MVU stages.
//! let batch: Vec<Tensor3> = (0..8).map(|_| Tensor3::zeros(64, 32, 32)).collect();
//! let streamed = session.run_stream(&batch)?;
//! println!("streaming speedup over serial: {:.2}x", streamed.stream.speedup());
//!
//! // metrics: cumulative across the session.
//! let m = session.metrics();
//! println!(
//!     "{} images, serial {:.0} / streamed {:.0} FPS at 250 MHz",
//!     m.images,
//!     m.serial_fps_at(barvinn::CLOCK_HZ),
//!     m.streamed_fps_at(barvinn::CLOCK_HZ),
//! );
//! # Ok(())
//! # }
//! ```
//!
//! With an [`ArtifactStore`], the session also owns the PJRT host prologue
//! and epilogue (conv0 / fc per §4.1) and serves raw f32 images end-to-end
//! through [`InferenceSession::run_image`]; it implements
//! [`crate::coordinator::Engine`], so it drops straight into the serving
//! coordinator (`examples/serve.rs`).
//!
//! **Execution backends** ([`crate::exec`]): `run()` defaults to
//! [`ExecMode::Turbo`] — the compiled job stream is replayed through the
//! job-level functional executor, which is bit-identical to the
//! cycle-accurate stepper in outputs and per-job cycle accounting but an
//! order of magnitude faster in wall-clock (no RISC-V interpretation).
//! Verification paths pin [`SessionBuilder::exec_mode`] to
//! [`ExecMode::CycleAccurate`], which drives the generated Pito program on
//! the modelled CPU and additionally reports true system cycles.
//!
//! **Deep models** (§3.1.6 "laps"): the pipelined map holds at most 8
//! layers. [`ExecutionMode::Auto`] (or explicit
//! [`ExecutionMode::MultiPass`]) schedules an N-layer model as ⌈N/8⌉
//! pipelined passes; `run()` reloads each pass's weights and program,
//! copies the previous pass's output into MVU 0's input region and sums
//! cycle accounting across passes — same bit-exact outputs under both
//! backends. Weight residency then rotates per pass, so deep sessions pay
//! a per-image reload ([`crate::codegen::MultiPassPlan::reload_words`]);
//! this is the run-time-programmability trade the paper makes against
//! per-model bitstream regeneration.
//!
//! **Streamed batches** (§3.1.6 dataflow): [`InferenceSession::run_stream`]
//! / [`InferenceSession::run_batch`] execute a batch with one frame per MVU
//! stage in flight over double-buffered activation regions — bit-identical
//! per-frame outputs, steady-state throughput set by the bottleneck stage
//! instead of the whole chain (the gap between
//! [`crate::perf::cycle_model::fps_pipelined`] and what serial `run` can
//! reach). Multi-pass sessions stream within each pass and amortise the
//! per-pass weight reload over the batch. See [`StreamMetrics`] for the
//! fill/steady/drain accounting and `docs/ARCHITECTURE.md` for the
//! dataflow diagram.
//!
//! All failure paths surface as the typed [`SessionError`] — no stringly
//! errors, no panicking asserts on [`SystemExit`].

use crate::accel::{LapStream, System, SystemConfig, SystemExit};
use crate::analysis::{Diagnostic, VerifyLevel};
use crate::exec::{ExecMode, StreamSchedule};
use crate::codegen::program::{CompiledModel, LayerPlan};
use crate::codegen::schedule::{DistributedPlan, MultiPassPlan};
use crate::codegen::{
    compile_distributed, compile_multi_pass, compile_pipelined, CompileError, EdgePolicy,
};
use crate::coordinator::Engine;
use crate::model::Model;
use crate::mvu::MvuConfig;
use crate::pito::Trap;
use crate::runtime::{ArtifactStore, HostModule, Runtime, RuntimeError};
use crate::sim::Tensor3;

/// §3.1.6 execution modes (Fig. 5), plus the depth-driven selector.
/// `Hash`/`Eq` so the mode can key serving caches
/// ([`crate::coordinator::ModelKey`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ExecutionMode {
    /// Layer `i` on MVU `i`, rows streamed between layers (max throughput);
    /// the model must fit the array (1..=8 layers).
    Pipelined,
    /// One layer split row-wise across all 8 MVUs (min latency); the model
    /// must be a single layer.
    Distributed,
    /// Deep models: ⌈N/8⌉ pipelined passes of ≤ 8 layers, activations
    /// carried between passes, weights reloaded per pass (§3.1.6 "laps").
    MultiPass,
    /// Resolve from model depth at build time: 1 layer → `Distributed`,
    /// 2..=8 → `Pipelined`, >8 → `MultiPass`.
    Auto,
}

impl std::fmt::Display for ExecutionMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ExecutionMode::Pipelined => "pipelined",
            ExecutionMode::Distributed => "distributed",
            ExecutionMode::MultiPass => "multi-pass",
            ExecutionMode::Auto => "auto",
        })
    }
}

/// Parse a CLI mode name (`pipelined` | `distributed` | `multipass` | `auto`).
impl std::str::FromStr for ExecutionMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "pipelined" => Ok(ExecutionMode::Pipelined),
            "distributed" => Ok(ExecutionMode::Distributed),
            "multipass" | "multi-pass" => Ok(ExecutionMode::MultiPass),
            "auto" => Ok(ExecutionMode::Auto),
            other => Err(format!(
                "unknown execution mode '{other}' (pipelined|distributed|multipass|auto)"
            )),
        }
    }
}

/// Scan CLI args for `--mode <pipelined|distributed|multipass|auto>`:
/// `Ok(default)` when the flag is absent, `Err(message)` when its value is
/// missing or invalid. Shared by `barvinn run` and `examples/serve.rs`
/// (mirrors [`crate::exec::parse_exec_arg`]).
pub fn parse_mode_arg(args: &[String], default: ExecutionMode) -> Result<ExecutionMode, String> {
    let Some(i) = args.iter().position(|a| a == "--mode") else {
        return Ok(default);
    };
    match args.get(i + 1) {
        None => Err("--mode requires a value (pipelined|distributed|multipass|auto)".into()),
        Some(v) => v.parse(),
    }
}

/// Typed inference error: every way a session can fail to build or run.
#[derive(Debug, Clone, PartialEq)]
pub enum SessionError {
    /// Model compilation failed (validation, mapping, codegen).
    Compile(CompileError),
    /// A hart took a fatal trap while driving the array.
    Fault { hart: usize, trap: Trap },
    /// Every hart asleep with no interrupt possible.
    Deadlock,
    /// The run exceeded the session's fuel limit.
    FuelExhausted { fuel: u64 },
    /// MVU job launches were rejected (bad CSR programming).
    Launch(Vec<String>),
    /// Host-side artifact / PJRT failure.
    Artifact(RuntimeError),
    /// The static verifier rejected the compiled plan at admission
    /// ([`SessionBuilder::verify`]).
    Verify(Vec<Diagnostic>),
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionError::Compile(e) => write!(f, "compile error: {e}"),
            SessionError::Fault { hart, trap } => {
                write!(f, "hart {hart} faulted: {trap:?}")
            }
            SessionError::Deadlock => write!(f, "deadlock: all harts asleep, no IRQ possible"),
            SessionError::FuelExhausted { fuel } => {
                write!(f, "fuel exhausted after {fuel} cycles")
            }
            SessionError::Launch(errs) => {
                write!(f, "{} job launch error(s): {}", errs.len(), errs.join("; "))
            }
            SessionError::Artifact(e) => write!(f, "artifact error: {e}"),
            SessionError::Verify(diags) => {
                write!(f, "static verification rejected the plan ({} finding(s)):", diags.len())?;
                for d in diags {
                    write!(f, "\n  {d}")?;
                }
                Ok(())
            }
        }
    }
}

impl std::error::Error for SessionError {}

impl From<CompileError> for SessionError {
    fn from(e: CompileError) -> Self {
        SessionError::Compile(e)
    }
}

impl From<RuntimeError> for SessionError {
    fn from(e: RuntimeError) -> Self {
        SessionError::Artifact(e)
    }
}

/// Which engine drives a streamed pipelined batch
/// ([`InferenceSession::run_stream`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StreamDriver {
    /// Resolve by backend: the generated multi-frame Pito program under
    /// the cycle-accurate backend (the modelled CPU executes the whole
    /// overlap), host-driven lap replay under turbo (the serving fast
    /// path). Outputs and cycle books are bit-identical either way.
    #[default]
    Auto,
    /// Always execute the generated streamed program on the modelled CPU.
    Program,
    /// Always replay the [`StreamSchedule`] laps from the host.
    HostLaps,
}

/// Builder for an [`InferenceSession`].
pub struct SessionBuilder {
    model: Model,
    policy: EdgePolicy,
    mode: ExecutionMode,
    exec: ExecMode,
    fuel: u64,
    mvu: MvuConfig,
    threads: usize,
    artifacts: Option<ArtifactStore>,
    host_input_shape: Vec<i64>,
    verify: VerifyLevel,
    stream_driver: StreamDriver,
}

impl SessionBuilder {
    /// Start a session over `model` with the defaults: pipelined execution,
    /// the turbo backend, `PadInRam` edges, the stock memory geometry and a
    /// 200 M-cycle fuel limit.
    pub fn new(model: Model) -> Self {
        SessionBuilder {
            model,
            policy: EdgePolicy::PadInRam,
            mode: ExecutionMode::Pipelined,
            exec: ExecMode::Turbo,
            fuel: crate::pito::BarrelConfig::default().max_cycles,
            mvu: MvuConfig::default(),
            threads: 1,
            artifacts: None,
            host_input_shape: vec![1, 3, 32, 32],
            verify: VerifyLevel::default(),
            stream_driver: StreamDriver::default(),
        }
    }

    /// How edge rows are handled (Table-3-exact `SkipEdges` vs full-output
    /// `PadInRam`).
    pub fn edge_policy(mut self, policy: EdgePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Scheduling mode: Pipelined (throughput), Distributed (latency),
    /// MultiPass (deep models) or Auto (resolve from model depth).
    pub fn mode(mut self, mode: ExecutionMode) -> Self {
        self.mode = mode;
        self
    }

    /// Execution backend for `run()`: job-level [`ExecMode::Turbo`]
    /// (default — serving speed) or the per-clock
    /// [`ExecMode::CycleAccurate`] stepper (timing ground truth). Outputs
    /// and per-job cycle accounting are bit-identical either way.
    pub fn exec_mode(mut self, exec: ExecMode) -> Self {
        self.exec = exec;
        self
    }

    /// Per-run cycle budget; exceeding it yields
    /// [`SessionError::FuelExhausted`] instead of spinning forever.
    pub fn fuel(mut self, cycles: u64) -> Self {
        self.fuel = cycles;
        self
    }

    /// Override the MVU memory geometry.
    pub fn mvu_config(mut self, cfg: MvuConfig) -> Self {
        self.mvu = cfg;
        self
    }

    /// Host worker threads for turbo streamed-lap execution (see
    /// [`crate::accel::SystemConfig::threads`]). Defaults to 1; results
    /// are bit-identical at any value — the knob trades host cores for
    /// wall-clock on batched/streamed runs.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Attach an artifact store: the model's `host_prologue` /
    /// `host_epilogue` HLO modules are compiled through PJRT at build time
    /// and [`InferenceSession::run_image`] becomes available.
    pub fn artifacts(mut self, store: ArtifactStore) -> Self {
        self.artifacts = Some(store);
        self
    }

    /// Shape of the raw image fed to the host prologue (defaults to CIFAR
    /// `[1, 3, 32, 32]`).
    pub fn host_input_shape(mut self, shape: &[i64]) -> Self {
        self.host_input_shape = shape.to_vec();
        self
    }

    /// Static-verification admission level (defaults to
    /// [`VerifyLevel::Quick`]): the compiled plan is abstract-interpreted
    /// before any cycle is simulated, and a non-clean
    /// [`crate::analysis::VerifyReport`] fails the build with
    /// [`SessionError::Verify`]. [`VerifyLevel::Off`] skips the gate;
    /// [`VerifyLevel::Full`] additionally cross-checks the symbolic bounds
    /// against captured job traces.
    pub fn verify(mut self, level: VerifyLevel) -> Self {
        self.verify = level;
        self
    }

    /// Which engine drives streamed batches (defaults to
    /// [`StreamDriver::Auto`]): the generated multi-frame Pito program on
    /// the cycle-accurate backend, host-driven lap replay on turbo.
    /// Override to pin one engine — e.g. [`StreamDriver::Program`] under
    /// turbo to exercise the program path fast, or
    /// [`StreamDriver::HostLaps`] under the stepper to reproduce the PR 5
    /// lap-replay timing.
    pub fn stream_driver(mut self, driver: StreamDriver) -> Self {
        self.stream_driver = driver;
        self
    }

    /// Compile the model, build the system and make all image-invariant
    /// state resident: weights, scalers, biases, the assembled program and
    /// (optionally) the compiled host modules. Multi-pass programs stage
    /// the per-pass weight images in the plan instead — RAM residency
    /// rotates pass by pass inside [`InferenceSession::run`].
    pub fn build(self) -> Result<InferenceSession, SessionError> {
        let n = self.model.layers.len();
        let mode = match self.mode {
            ExecutionMode::Auto => {
                if n == 1 {
                    ExecutionMode::Distributed
                } else if n <= crate::NUM_MVUS {
                    ExecutionMode::Pipelined
                } else {
                    ExecutionMode::MultiPass
                }
            }
            m => m,
        };
        let program = match mode {
            ExecutionMode::Pipelined => {
                Program::Pipelined(compile_pipelined(&self.model, self.policy)?)
            }
            ExecutionMode::MultiPass => {
                Program::MultiPass(compile_multi_pass(&self.model, self.policy)?)
            }
            ExecutionMode::Distributed => {
                if n != 1 {
                    return Err(SessionError::Compile(CompileError::Mode(format!(
                        "distributed mode maps a single layer across the array, got {n} \
                         layers (pipelined handles 2..=8; ExecutionMode::Auto / --mode auto \
                         picks multi-pass for deeper models)"
                    ))));
                }
                self.model.validate().map_err(CompileError::InvalidModel)?;
                Program::Distributed(compile_distributed(&self.model.layers[0], self.policy)?)
            }
            ExecutionMode::Auto => unreachable!("Auto resolved to a concrete mode above"),
        };

        let cfg = SystemConfig {
            mvu: self.mvu,
            barrel: crate::pito::BarrelConfig { max_cycles: self.fuel, ..Default::default() },
            exec: self.exec,
            threads: self.threads,
        };
        let mut sys = System::new(cfg);
        match &program {
            Program::Pipelined(c) => {
                c.check_fits(&self.mvu)?;
                c.load_weights(&mut sys);
            }
            Program::Distributed(p) => {
                p.check_fits(&self.mvu)?;
                p.load_weights(&mut sys, &self.model.layers[0]);
            }
            // Weights rotate per pass inside run(): nothing to pre-load,
            // but every pass must fit the geometry before we accept it.
            Program::MultiPass(p) => p.check_fits(&self.mvu)?,
        }

        // Admission gate: the capacity checks above bound totals; the
        // verifier proves address safety, def-before-use, stream-race
        // freedom, sync liveness and cycle-budget consistency of the
        // command stream itself.
        let report = match &program {
            Program::Pipelined(c) => {
                crate::analysis::verify_pipelined(c, &self.model, &self.mvu, self.verify)
            }
            Program::Distributed(p) => crate::analysis::verify_distributed(
                p,
                &self.model.layers[0],
                &self.mvu,
                self.verify,
            ),
            Program::MultiPass(p) => {
                crate::analysis::verify_multi_pass(p, &self.model, &self.mvu, self.verify)
            }
        };
        if !report.is_clean() {
            return Err(SessionError::Verify(report.diagnostics));
        }

        let host = match self.artifacts {
            None => None,
            Some(store) => {
                let runtime = Runtime::cpu()?;
                let load = |name: &Option<String>| -> Result<Option<HostModule>, SessionError> {
                    match name {
                        None => Ok(None),
                        Some(n) => Ok(Some(runtime.load_hlo_text(&store.hlo_path(n))?)),
                    }
                };
                let prologue = load(&self.model.host_prologue)?;
                let epilogue = load(&self.model.host_epilogue)?;
                Some(HostPipeline {
                    _runtime: runtime,
                    prologue,
                    epilogue,
                    input_shape: self.host_input_shape,
                })
            }
        };

        Ok(InferenceSession {
            model: self.model,
            program,
            sys,
            host,
            fuel: self.fuel,
            mvu_cfg: self.mvu,
            images_run: 0,
            total_mvu_cycles: 0,
            total_system_cycles: 0,
            total_bottleneck_cycles: 0,
            streamed_images: 0,
            total_pipeline_cycles: 0,
            stream_driver: self.stream_driver,
            stream_program_resident: false,
            open_stream: None,
        })
    }
}

/// The compiled command stream, by execution mode.
enum Program {
    Pipelined(CompiledModel),
    Distributed(DistributedPlan),
    MultiPass(MultiPassPlan),
}

/// PJRT host prologue/epilogue owned by the session.
struct HostPipeline {
    _runtime: Runtime,
    prologue: Option<HostModule>,
    epilogue: Option<HostModule>,
    input_shape: Vec<i64>,
}

/// Result of one accelerator run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunOutput {
    /// The final activation tensor.
    pub output: Tensor3,
    /// Per-MVU busy cycles for this image. Pipelined mode: one entry per
    /// MVU (= per layer); multi-pass mode: one entry per *layer* across
    /// all passes, in model order (the array is time-multiplexed, so
    /// per-MVU totals would conflate layers). Backend-invariant: turbo
    /// books the same per-job counts as the stepper.
    pub mvu_cycles: Vec<u64>,
    /// Sum of MVU busy cycles for this image.
    pub total_mvu_cycles: u64,
    /// Global system cycles for this image (multi-pass: summed over
    /// passes). Under the cycle-accurate backend this includes CPU
    /// orchestration; under turbo it advances by MVP job cycles only.
    pub system_cycles: u64,
    /// 0-based index of this image within the session.
    pub image_index: u64,
    /// Execution backend that served this run.
    pub exec: ExecMode,
}

/// Result of a full host-prologue → array → host-epilogue run.
#[derive(Debug, Clone, PartialEq)]
pub struct HostRunOutput {
    /// Epilogue output (the classifier logits).
    pub logits: Vec<f32>,
    /// The accelerator-portion stats and activations.
    pub accel: RunOutput,
}

/// Cumulative session counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SessionMetrics {
    pub images: u64,
    pub total_mvu_cycles: u64,
    pub total_system_cycles: u64,
    /// Sum over runs of the *slowest* MVU's busy cycles — the pipeline
    /// bottleneck stage, which bounds steady-state throughput. Multi-pass
    /// runs sum the bottleneck of every pass (the lap model behind
    /// [`crate::perf::cycle_model::fps_pipelined`]).
    pub total_bottleneck_cycles: u64,
    /// Images that executed through the streamed pipeline
    /// ([`InferenceSession::run_stream`]) with up to 8 frames in flight.
    pub streamed_images: u64,
    /// Modelled wall cycles (fill + steady + drain) of every streamed
    /// batch, summed. `streamed_images / total_pipeline_cycles` is the
    /// *achieved* streamed rate, including fill/drain overhead.
    pub total_pipeline_cycles: u64,
}

impl SessionMetrics {
    /// Mean MVU cycles per image (0 when nothing ran).
    pub fn mean_mvu_cycles(&self) -> u64 {
        if self.images == 0 {
            0
        } else {
            self.total_mvu_cycles / self.images
        }
    }

    /// FPS the serial one-image-at-a-time path actually achieves at
    /// `clock_hz`: each `run()` walks the whole chain before the next
    /// image enters, so the per-image cost is the mean *total* MVP cycles.
    pub fn serial_fps_at(&self, clock_hz: u64) -> f64 {
        if self.images == 0 || self.total_mvu_cycles == 0 {
            return 0.0;
        }
        clock_hz as f64 / (self.total_mvu_cycles as f64 / self.images as f64)
    }

    /// Achieved FPS of the streamed batches at `clock_hz`: frames divided
    /// by the modelled batch wall cycles (fill + steady-state bottleneck
    /// laps + drain). 0 when nothing streamed. Sits between
    /// [`Self::serial_fps_at`] and [`Self::steady_state_fps_bound_at`],
    /// approaching the bound as batches grow.
    pub fn streamed_fps_at(&self, clock_hz: u64) -> f64 {
        if self.streamed_images == 0 || self.total_pipeline_cycles == 0 {
            return 0.0;
        }
        clock_hz as f64 / (self.total_pipeline_cycles as f64 / self.streamed_images as f64)
    }

    /// Steady-state FPS *bound* of the pipeline at `clock_hz`: one frame
    /// per bottleneck lap (a distributed run: per slowest chunk) — the
    /// lap model of [`crate::perf::cycle_model::fps_pipelined`]. Serial
    /// execution never reaches it; streamed batches approach it as fill
    /// and drain amortise.
    pub fn steady_state_fps_bound_at(&self, clock_hz: u64) -> f64 {
        if self.images == 0 || self.total_bottleneck_cycles == 0 {
            return 0.0;
        }
        clock_hz as f64 / (self.total_bottleneck_cycles as f64 / self.images as f64)
    }

}

/// Cycle accounting of one streamed batch: the fill + steady-state + drain
/// lap model ([`StreamSchedule`]), plus what the serial path would have
/// paid — the measured counterpart of
/// [`crate::perf::cycle_model::fps_pipelined`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StreamMetrics {
    /// Frames in the batch.
    pub frames: u64,
    /// Pipeline stages frames flowed through — the maximum frames in
    /// flight (multi-pass: the widest pass).
    pub stages: usize,
    /// Cycles spent filling the pipeline (leading stages idle).
    pub fill_cycles: u64,
    /// Steady-state cycles: every stage busy, one frame retiring per
    /// bottleneck lap.
    pub steady_cycles: u64,
    /// Cycles draining the pipeline after the last frame entered.
    pub drain_cycles: u64,
    /// `fill + steady + drain` — modelled wall cycles for the batch
    /// (multi-pass: summed over passes).
    pub pipeline_cycles: u64,
    /// Steady-state cost per frame: the bottleneck stage's cycles
    /// (multi-pass: per-pass bottlenecks summed).
    pub bottleneck_cycles: u64,
    /// What serial `run()` would cost for the same frames: per-frame MVP
    /// totals, summed.
    pub serial_cycles: u64,
    /// Wall cycles the system clock actually advanced executing the batch.
    /// Equals `pipeline_cycles` under turbo laps; host-driven
    /// cycle-accurate laps add short crossbar-drain tails between laps,
    /// and the program-driven engine ([`StreamDriver::Program`])
    /// additionally books the modelled CPU's flag-spin and launch
    /// overhead. Every other field is engine-invariant.
    pub measured_cycles: u64,
}

impl StreamMetrics {
    /// Achieved streamed FPS at `clock_hz` (includes fill/drain overhead).
    pub fn streamed_fps_at(&self, clock_hz: u64) -> f64 {
        if self.frames == 0 || self.pipeline_cycles == 0 {
            return 0.0;
        }
        clock_hz as f64 * self.frames as f64 / self.pipeline_cycles as f64
    }

    /// What the serial path would have achieved on the same frames.
    pub fn serial_fps_at(&self, clock_hz: u64) -> f64 {
        if self.frames == 0 || self.serial_cycles == 0 {
            return 0.0;
        }
        clock_hz as f64 * self.frames as f64 / self.serial_cycles as f64
    }

    /// Streaming speedup over serial execution (1.0 when degenerate).
    pub fn speedup(&self) -> f64 {
        if self.pipeline_cycles == 0 {
            return 1.0;
        }
        self.serial_cycles as f64 / self.pipeline_cycles as f64
    }

    /// Fraction of stage-cycle slots doing useful work:
    /// `serial_cycles / (pipeline_cycles · stages)`. 1.0 means a perfectly
    /// balanced, fully occupied pipeline; fill/drain and stage imbalance
    /// pull it down.
    pub fn occupancy(&self) -> f64 {
        let slots = self.pipeline_cycles.saturating_mul(self.stages as u64);
        if slots == 0 {
            return 0.0;
        }
        self.serial_cycles as f64 / slots as f64
    }
}

/// Result of one streamed batch: per-frame outputs (bit-identical to what
/// serial [`InferenceSession::run`] would produce, in submission order)
/// plus the batch-level pipeline accounting.
#[derive(Debug, Clone)]
pub struct StreamOutput {
    pub outputs: Vec<RunOutput>,
    pub stream: StreamMetrics,
}

/// Per-frame `(output tensor, per-stage MVP cycles)` pairs, in frame
/// order — the raw currency of the streaming drivers below.
type FrameResults = Vec<(Tensor3, Vec<u64>)>;

/// An online frame feed for [`InferenceSession::run_continuous`]: frames
/// tagged with the lap at which they become available. A frame joins the
/// *running* pipeline at the fill boundary `max(arrival, previous entry +
/// 1)` — it never waits for the current batch to drain. [`Self::push`]
/// models a frame that is already waiting (a closed batch is all frames
/// pushed at arrival 0); [`Self::push_at`] models a frame arriving
/// mid-stream, which may leave pipeline bubbles the accounting charges at
/// the bottleneck rate.
#[derive(Debug, Clone, Default)]
pub struct StreamFeed {
    /// `(input, arrival lap)` in admission order; arrival laps are
    /// clamped monotone on push.
    frames: Vec<(Tensor3, usize)>,
}

impl StreamFeed {
    pub fn new() -> Self {
        StreamFeed::default()
    }

    /// Feed a frame that is ready now (arrival lap 0 — or, mid-feed, the
    /// previous frame's arrival: admission order is the feed order).
    pub fn push(&mut self, input: Tensor3) {
        let at = self.frames.last().map(|&(_, a)| a).unwrap_or(0);
        self.push_at(input, at);
    }

    /// Feed a frame that arrives at `arrival_lap`. Arrivals are a trace in
    /// time: a lap earlier than the previous frame's arrival is clamped up
    /// to it (frames cannot arrive out of order within one feed).
    pub fn push_at(&mut self, input: Tensor3, arrival_lap: usize) {
        let at = match self.frames.last() {
            Some(&(_, prev)) => arrival_lap.max(prev),
            None => arrival_lap,
        };
        self.frames.push((input, at));
    }

    pub fn len(&self) -> usize {
        self.frames.len()
    }

    pub fn is_empty(&self) -> bool {
        self.frames.is_empty()
    }

    /// The arrival lap of each frame, in feed order.
    pub fn arrivals(&self) -> Vec<usize> {
        self.frames.iter().map(|&(_, a)| a).collect()
    }

    /// Borrow the frames in feed order (the inputs of the batch).
    pub fn inputs(&self) -> Vec<Tensor3> {
        self.frames.iter().map(|(t, _)| t.clone()).collect()
    }
}

/// Persistent open-pipeline accounting for the serving path (see
/// [`InferenceSession::open_pipeline`]): the schedule grows across
/// `run_batch` calls, so only the first admission pays fill and the drain
/// is deferred until [`InferenceSession::close_pipeline`].
struct OpenPipeline {
    sched: StreamSchedule,
    /// Laps already booked into the session counters / returned metrics.
    booked_laps: usize,
}

/// A warm, weight-resident inference session over the simulated
/// accelerator. See the [module docs](self) for the lifecycle.
pub struct InferenceSession {
    model: Model,
    program: Program,
    sys: System,
    host: Option<HostPipeline>,
    /// The image-level cycle budget from the builder. Multi-pass runs
    /// re-arm the system's remaining fuel before each pass, so this keeps
    /// the original budget for error reporting; streamed batches scale it
    /// by the frame count.
    fuel: u64,
    /// The memory geometry the session was built for — streamed batches
    /// re-check capacity against it (double buffering needs twice the
    /// activation footprint serial execution does).
    mvu_cfg: MvuConfig,
    images_run: u64,
    total_mvu_cycles: u64,
    total_system_cycles: u64,
    total_bottleneck_cycles: u64,
    streamed_images: u64,
    total_pipeline_cycles: u64,
    stream_driver: StreamDriver,
    /// A program-driven streamed batch left its multi-frame program in
    /// IRAM; the next serial `run()` must re-load the serial program.
    stream_program_resident: bool,
    /// `Some` once [`Self::open_pipeline`] armed continuous-admission
    /// accounting: `run_batch` chunks admit into this one growing schedule
    /// instead of booking closed fill+drain per flush.
    open_stream: Option<OpenPipeline>,
}

impl InferenceSession {
    /// The model this session serves.
    pub fn model(&self) -> &Model {
        &self.model
    }

    /// The execution backend serving `run()` — held by the embedded
    /// [`System`], the single source of truth `run_job` dispatches on.
    pub fn exec_mode(&self) -> ExecMode {
        self.sys.exec_mode()
    }

    /// The concrete execution mode this session compiled to (never
    /// [`ExecutionMode::Auto`] — that is resolved at build time).
    pub fn execution_mode(&self) -> ExecutionMode {
        match &self.program {
            Program::Pipelined(_) => ExecutionMode::Pipelined,
            Program::Distributed(_) => ExecutionMode::Distributed,
            Program::MultiPass(_) => ExecutionMode::MultiPass,
        }
    }

    /// Scheduling passes per image: 1 for single-pass modes, ⌈layers/8⌉
    /// under multi-pass.
    pub fn n_passes(&self) -> usize {
        match &self.program {
            Program::MultiPass(p) => p.n_passes(),
            _ => 1,
        }
    }

    /// The generated RISC-V assembly listing (multi-pass: all passes,
    /// concatenated in execution order).
    pub fn asm(&self) -> &str {
        match &self.program {
            Program::Pipelined(c) => &c.asm,
            Program::Distributed(p) => &p.asm,
            Program::MultiPass(p) => &p.asm,
        }
    }

    /// Instruction count of the loaded program (multi-pass: summed over
    /// every pass's program).
    pub fn program_len(&self) -> usize {
        match &self.program {
            Program::Pipelined(c) => c.program.len(),
            Program::Distributed(p) => p.program.len(),
            Program::MultiPass(p) => p.program_len(),
        }
    }

    /// Weight + scaler + bias RAM words made resident **once at build**
    /// and reused across images: exactly the reload a serving-fleet cache
    /// hit avoids re-paying when a warm session is reused instead of
    /// rebuilt ([`crate::coordinator::Fleet`]). Multi-pass sessions report
    /// 0 — their RAM images rotate *per image* inside `run()` regardless
    /// of session warmth (see [`Self::per_image_reload_words`]), so a
    /// rebuild costs compilation but no extra RAM loading.
    pub fn resident_words(&self) -> u64 {
        match &self.program {
            Program::Pipelined(c) => c.resident_words(),
            Program::Distributed(p) => p.resident_words(),
            Program::MultiPass(_) => 0,
        }
    }

    /// RAM words re-loaded on **every** image independent of session
    /// warmth: [`MultiPassPlan::reload_words`] for multi-pass sessions
    /// (the §3.1.6 lap cost), 0 for single-pass modes. Routing policy and
    /// caching cannot change this term — keep it out of cache hit/miss
    /// accounting.
    pub fn per_image_reload_words(&self) -> u64 {
        match &self.program {
            Program::MultiPass(p) => p.reload_words(),
            _ => 0,
        }
    }

    /// Per-MVU digest of the current activation-RAM contents (FNV-1a over
    /// every word, address order). Execution strategies that promise
    /// bit-identical *machine state* — serial vs streamed vs continuous
    /// admission, either backend — must leave identical digests; the
    /// admission property test pins exactly that without exposing the RAMs.
    pub fn activation_ram_digest(&self) -> Vec<u64> {
        self.sys
            .mvus
            .iter()
            .map(|m| {
                let mut h: u64 = 0xcbf2_9ce4_8422_2325;
                for addr in 0..m.act.depth() as u32 {
                    h ^= m.act.read(addr);
                    h = h.wrapping_mul(0x1000_0000_01b3);
                }
                h
            })
            .collect()
    }

    /// Cumulative counters across all completed runs.
    pub fn metrics(&self) -> SessionMetrics {
        SessionMetrics {
            images: self.images_run,
            total_mvu_cycles: self.total_mvu_cycles,
            total_system_cycles: self.total_system_cycles,
            total_bottleneck_cycles: self.total_bottleneck_cycles,
            streamed_images: self.streamed_images,
            total_pipeline_cycles: self.total_pipeline_cycles,
        }
    }

    /// Run one quantized input image through the array and return the final
    /// activations plus cycle accounting.
    ///
    /// Single-pass modes reset only activation state between calls;
    /// weights, scalers, biases and the program stay resident from
    /// [`SessionBuilder::build`]. Multi-pass mode additionally reloads each
    /// pass's weights and program as the array is time-multiplexed through
    /// the deep model, carrying activations between passes and honouring
    /// the fuel budget *across* passes. Dispatches on the configured
    /// [`ExecMode`] — see the module docs for when each backend is
    /// authoritative.
    pub fn run(&mut self, input: &Tensor3) -> Result<RunOutput, SessionError> {
        let multi = matches!(self.program, Program::MultiPass(_));
        let (output, mvu_cycles, system_cycles, bottleneck) = if multi {
            self.exec_multi_pass(input)?
        } else {
            self.exec_single(input)?
        };
        let total_mvu_cycles: u64 = mvu_cycles.iter().sum();
        let image_index = self.images_run;
        self.images_run += 1;
        self.total_mvu_cycles += total_mvu_cycles;
        self.total_system_cycles += system_cycles;
        self.total_bottleneck_cycles += bottleneck;
        Ok(RunOutput {
            output,
            mvu_cycles,
            total_mvu_cycles,
            system_cycles,
            image_index,
            exec: self.sys.exec_mode(),
        })
    }

    /// One warm single-pass run: reset activation state, load the input,
    /// drive, read back `(output, per-MVU cycles, system cycles,
    /// bottleneck-stage cycles)`.
    fn exec_single(
        &mut self,
        input: &Tensor3,
    ) -> Result<(Tensor3, Vec<u64>, u64, u64), SessionError> {
        self.sys.reset_run_state();
        // Re-arm the per-image budget: a preceding streamed batch ran the
        // system under the whole-batch cap (`fuel × frames`).
        self.sys.set_max_cycles(self.fuel);
        if self.stream_program_resident {
            if let Program::Pipelined(c) = &self.program {
                self.sys.load_program(&c.program);
            }
            self.stream_program_resident = false;
        }
        match &self.program {
            Program::Pipelined(c) => c.load_input(&mut self.sys, input),
            Program::Distributed(p) => p.load_input(&mut self.sys, input),
            Program::MultiPass(_) => unreachable!("multi-pass handled by exec_multi_pass"),
        }

        match self.sys.exec_mode() {
            ExecMode::CycleAccurate => drive_cycle_accurate(&mut self.sys, self.fuel)?,
            ExecMode::Turbo => match &self.program {
                Program::Pipelined(c) => {
                    drive_pipelined_turbo(&mut self.sys, &c.plans, self.fuel)?
                }
                Program::Distributed(p) => {
                    drive_distributed_turbo(&mut self.sys, p, self.fuel)?
                }
                Program::MultiPass(_) => unreachable!("multi-pass handled by exec_multi_pass"),
            },
        }

        let output = match &self.program {
            Program::Pipelined(c) => {
                c.read_output(&self.sys, self.model.layers.last().unwrap().co)
            }
            Program::Distributed(p) => p.read_output(&self.sys, &self.model.layers[0]),
            Program::MultiPass(_) => unreachable!("multi-pass handled by exec_multi_pass"),
        };
        let mvu_cycles: Vec<u64> = self.sys.mvus.iter().map(|m| m.busy_cycles()).collect();
        let bottleneck = mvu_cycles.iter().max().copied().unwrap_or(0);
        Ok((output, mvu_cycles, self.sys.cycles(), bottleneck))
    }

    /// One multi-pass run over a deep model. Per pass `p`: reset run
    /// state, re-arm the *remaining* fuel, reload pass `p`'s weight,
    /// scaler and bias RAMs and its program, load the carried activations
    /// (the raw input for pass 0) into MVU 0, drive with the configured
    /// backend, then read the last MVU's output region as the next pass's
    /// input — the host-DMA copy of §3.1.6's lap schedule. Returns per
    /// *layer* MVU cycles (model order) and the per-pass-bottleneck sum.
    fn exec_multi_pass(
        &mut self,
        input: &Tensor3,
    ) -> Result<(Tensor3, Vec<u64>, u64, u64), SessionError> {
        let Program::MultiPass(plan) = &self.program else {
            unreachable!("exec_multi_pass requires a multi-pass program")
        };
        let fuel = self.fuel;
        let mut spent = 0u64;
        let mut mvu_cycles: Vec<u64> = Vec::with_capacity(self.model.layers.len());
        let mut bottleneck = 0u64;
        let mut carried: Option<Tensor3> = None;
        for (p, pass) in plan.passes.iter().enumerate() {
            if spent >= fuel {
                return Err(SessionError::FuelExhausted { fuel });
            }
            self.sys.reset_run_state();
            self.sys.set_max_cycles(fuel - spent);
            pass.load_weights(&mut self.sys);
            match &carried {
                None => pass.load_input(&mut self.sys, input),
                Some(t) => pass.load_input(&mut self.sys, t),
            }
            match self.sys.exec_mode() {
                ExecMode::CycleAccurate => drive_cycle_accurate(&mut self.sys, fuel)?,
                ExecMode::Turbo => drive_pipelined_turbo(&mut self.sys, &pass.plans, fuel)?,
            }
            spent += self.sys.cycles();
            let mut pass_max = 0u64;
            for layer_plan in &pass.plans {
                let c = self.sys.mvus[layer_plan.mvu].busy_cycles();
                pass_max = pass_max.max(c);
                mvu_cycles.push(c);
            }
            bottleneck += pass_max;
            let (_, end) = plan.ranges[p];
            let out = pass.read_output(&self.sys, self.model.layers[end - 1].co);
            if p + 1 < plan.passes.len() {
                carried = Some(out);
            } else {
                return Ok((out, mvu_cycles, spent, bottleneck));
            }
        }
        unreachable!("compile_multi_pass guarantees at least one pass")
    }

    /// Run a batch of images through the array with up to 8 frames in
    /// flight — the streamed pipeline of §3.1.6 that the paper's
    /// throughput headline assumes.
    ///
    /// Pipelined sessions keep one frame per MVU stage: while stage `k`
    /// processes frame `i`, stage `k−1` already processes frame `i+1`,
    /// over double-buffered activation regions (even frames in buffer 0,
    /// odd in buffer 1) so in-flight frames never clobber each other.
    ///
    /// Two engines can drive that overlap ([`SessionBuilder::stream_driver`]):
    /// under the cycle-accurate backend the session executes the
    /// **generated multi-frame Pito program**
    /// ([`CompiledModel::stream_program`]) — the parity discipline and all
    /// fill/drain synchronisation live in the instruction stream, the host
    /// only staging inputs and reading outputs at the DRAM flag protocol's
    /// pace, exactly the paper's control model; under turbo the host
    /// replays the [`StreamSchedule`] laps directly (the serving fast
    /// path). Outputs and per-frame cycle books are bit-identical across
    /// drivers; only [`StreamMetrics::measured_cycles`] is path-dependent
    /// (the program-driven wall includes the CPU's launch overhead).
    /// Multi-pass sessions stream the whole batch *within* each pass — a
    /// further win: each pass's weights are reloaded once per batch
    /// instead of once per image. Distributed sessions have nothing to
    /// overlap (one frame occupies the whole array) and fall back to the
    /// serial loop.
    ///
    /// Per-frame outputs are **bit-identical** to serial [`Self::run`] in
    /// both execution backends, in submission order — concurrent stages
    /// touch disjoint frames and buffers, and every lap ends with the
    /// crossbar drained. Per-frame [`RunOutput::mvu_cycles`] books the
    /// same per-layer counts as serial runs; the batch-level fill +
    /// steady-state + drain wall model lives in [`StreamOutput::stream`]
    /// (`RunOutput::system_cycles` of a streamed frame is its own MVP
    /// total — frames share the wall clock, so per-frame wall time is not
    /// meaningful). The session's fuel budget scales with the batch:
    /// `fuel × frames` cycles for the whole stream.
    ///
    /// Streaming needs twice the activation footprint of serial execution;
    /// a model that fits serially but cannot double-buffer fails with a
    /// typed [`CompileError::StreamOverlap`] / `CapacityExceeded` before
    /// touching the array.
    pub fn run_stream(&mut self, inputs: &[Tensor3]) -> Result<StreamOutput, SessionError> {
        self.run_stream_with(inputs, None)
    }

    /// Continuous admission: stream an online [`StreamFeed`] whose frames
    /// join the *running* pipeline at the fill boundary instead of waiting
    /// for a batch to close. Outputs and per-frame cycle books are
    /// **bit-identical** to serial [`Self::run`] and to closed
    /// [`Self::run_batch`] of the same frames under both backends and both
    /// stream drivers — admission timing shapes only the lap schedule (and
    /// so the fill/steady/drain accounting, which charges feed gaps longer
    /// than the pipeline depth as bottleneck-rate bubbles). Under the
    /// program driver the host admits by bumping `HOST_IN` between
    /// `poll_step`s — one frame per service pass, a posting schedule
    /// statically validated against the two-frame buffer contract
    /// ([`crate::analysis::verify_host_posting`]) before the CPU runs.
    /// Multi-pass sessions admit online into pass 0; later passes stream
    /// the carried outputs as a dense batch (all frames are on hand).
    pub fn run_continuous(&mut self, feed: &StreamFeed) -> Result<StreamOutput, SessionError> {
        if feed.is_empty() {
            return Ok(StreamOutput { outputs: Vec::new(), stream: StreamMetrics::default() });
        }
        let inputs = feed.inputs();
        let arrivals = feed.arrivals();
        self.run_stream_with(&inputs, Some(&arrivals))
    }

    /// Shared streaming core: `arrivals` of `None` is the closed batch
    /// (every frame admitted at lap 0); `Some` is continuous admission at
    /// the given arrival laps.
    fn run_stream_with(
        &mut self,
        inputs: &[Tensor3],
        arrivals: Option<&[usize]>,
    ) -> Result<StreamOutput, SessionError> {
        if inputs.is_empty() {
            return Ok(StreamOutput { outputs: Vec::new(), stream: StreamMetrics::default() });
        }
        if matches!(self.program, Program::Distributed(_)) {
            return self.run_stream_serial(inputs);
        }
        let exec = self.sys.exec_mode();
        let fuel = self.fuel;
        // Which engine executes the overlap: the generated multi-frame
        // Pito program on the modelled CPU, or host-driven lap replay.
        let program_driven = match self.stream_driver {
            StreamDriver::Auto => exec == ExecMode::CycleAccurate,
            StreamDriver::Program => true,
            StreamDriver::HostLaps => false,
        };
        let (raw, stream) = match &self.program {
            Program::Pipelined(c) => {
                c.check_fits_streamed(&self.mvu_cfg)?;
                self.sys.reset_run_state();
                self.sys.set_max_cycles(fuel.saturating_mul(inputs.len() as u64));
                let co = self.model.layers.last().unwrap().co;
                let (mut raw, stream) =
                    stream_compiled(&mut self.sys, c, inputs, co, fuel, program_driven, arrivals)?;
                // Serial pipelined runs report one entry per MVU (trailing
                // zeros for unused stages); match that shape bit-for-bit.
                for (_, cycles) in &mut raw {
                    cycles.resize(crate::NUM_MVUS, 0);
                }
                (raw, stream)
            }
            Program::MultiPass(p) => {
                p.check_fits_streamed(&self.mvu_cfg)?;
                stream_multi_pass(
                    &mut self.sys,
                    p,
                    &self.model,
                    inputs,
                    fuel,
                    program_driven,
                    arrivals,
                )?
            }
            Program::Distributed(_) => unreachable!("serial fallback handled above"),
        };
        // The streamed program (not the serial one) is now resident in
        // IRAM; the next serial run reloads. Multi-pass serial runs reload
        // per pass anyway, but the flag is cheap and uniform.
        if program_driven {
            self.stream_program_resident = true;
        }
        let mut outputs = Vec::with_capacity(raw.len());
        for (output, mvu_cycles) in raw {
            let total_mvu_cycles: u64 = mvu_cycles.iter().sum();
            outputs.push(RunOutput {
                output,
                mvu_cycles,
                total_mvu_cycles,
                system_cycles: total_mvu_cycles,
                image_index: self.images_run,
                exec,
            });
            self.images_run += 1;
            self.total_mvu_cycles += total_mvu_cycles;
            self.total_bottleneck_cycles += stream.bottleneck_cycles;
        }
        self.total_system_cycles += stream.measured_cycles;
        self.streamed_images += stream.frames;
        self.total_pipeline_cycles += stream.pipeline_cycles;
        Ok(StreamOutput { outputs, stream })
    }

    /// Serving-facing entry: the coordinator's key-homogeneous batches
    /// execute through this path (see `perf::serve_bench::SessionEngine`).
    /// Without [`Self::open_pipeline`] it is [`Self::run_stream`]; with it,
    /// each flush *admits into one open pipeline* — execution (and thus
    /// every output bit) is unchanged, but the accounting books this flush
    /// as dense admissions continuing the running schedule: fill is paid
    /// once at the first flush, flush boundaries become admission points
    /// booking steady laps, and the drain tail is deferred to
    /// [`Self::close_pipeline`].
    pub fn run_batch(&mut self, inputs: &[Tensor3]) -> Result<StreamOutput, SessionError> {
        if self.open_stream.is_none() || inputs.is_empty() {
            return self.run_stream(inputs);
        }
        let mut out = self.run_stream(inputs)?;
        let open = self.open_stream.as_mut().unwrap();
        for _ in 0..inputs.len() {
            open.sched.admit(0); // dense continuation: next fill boundary
        }
        let end = open.sched.entry_lap(open.sched.frames() - 1) + 1;
        let cyc = open.sched.cycles_between(open.booked_laps..end);
        open.booked_laps = end;
        // Swap the flush's closed fill+steady+drain for the open window.
        self.total_pipeline_cycles =
            self.total_pipeline_cycles - out.stream.pipeline_cycles + cyc.total();
        out.stream.fill_cycles = cyc.fill;
        out.stream.steady_cycles = cyc.steady;
        out.stream.drain_cycles = cyc.drain;
        out.stream.pipeline_cycles = cyc.total();
        Ok(out)
    }

    /// Arm continuous-admission accounting for the serving path: `true`
    /// once subsequent [`Self::run_batch`] flushes feed one open pipeline.
    /// Only pipelined programs have a single persistent pipeline to hold
    /// open; distributed and multi-pass sessions return `false` and keep
    /// closed-batch accounting.
    pub fn open_pipeline(&mut self) -> bool {
        match &self.program {
            Program::Pipelined(c) => {
                self.open_stream =
                    Some(OpenPipeline { sched: StreamSchedule::open(c.stage_cycles()), booked_laps: 0 });
                true
            }
            _ => {
                self.open_stream = None;
                false
            }
        }
    }

    /// Drain the open pipeline: book the deferred tail laps and return
    /// their accounting (zero frames — the frames were already reported by
    /// their admitting flushes). The pipeline re-opens empty, so the next
    /// flush starts a fresh stream (and pays fill again).
    pub fn close_pipeline(&mut self) -> StreamMetrics {
        let Some(open) = self.open_stream.as_mut() else {
            return StreamMetrics::default();
        };
        let cyc = open.sched.cycles_between(open.booked_laps..usize::MAX);
        let stream = StreamMetrics {
            frames: 0,
            stages: open.sched.stages(),
            fill_cycles: cyc.fill,
            steady_cycles: cyc.steady,
            drain_cycles: cyc.drain,
            pipeline_cycles: cyc.total(),
            bottleneck_cycles: open.sched.bottleneck_cycles(),
            serial_cycles: 0,
            measured_cycles: 0,
        };
        self.total_pipeline_cycles += cyc.total();
        self.open_pipeline();
        stream
    }

    /// Distributed-mode fallback: no pipeline to stream (a single frame
    /// already occupies all 8 MVUs), so the batch runs serially; the
    /// stream accounting degenerates to `pipeline == serial` (speedup 1),
    /// which keeps the serving telemetry honest. Serial `run` updates the
    /// session counters itself, and no streamed counters are booked.
    fn run_stream_serial(&mut self, inputs: &[Tensor3]) -> Result<StreamOutput, SessionError> {
        let bottleneck0 = self.total_bottleneck_cycles;
        let mut outputs = Vec::with_capacity(inputs.len());
        let mut serial = 0u64;
        let mut measured = 0u64;
        for input in inputs {
            let out = self.run(input)?;
            serial += out.total_mvu_cycles;
            measured += out.system_cycles;
            outputs.push(out);
        }
        let frames = inputs.len() as u64;
        let stream = StreamMetrics {
            frames,
            stages: 1,
            fill_cycles: 0,
            steady_cycles: serial,
            drain_cycles: 0,
            pipeline_cycles: serial,
            bottleneck_cycles: (self.total_bottleneck_cycles - bottleneck0) / frames,
            serial_cycles: serial,
            measured_cycles: measured,
        };
        Ok(StreamOutput { outputs, stream })
    }

    /// Run one raw f32 image through host prologue → MVU array → host
    /// epilogue. Requires the session to have been built with
    /// [`SessionBuilder::artifacts`] and the model to name both host
    /// modules.
    pub fn run_image(&mut self, image: &[f32]) -> Result<HostRunOutput, SessionError> {
        let l0 = self
            .model
            .layers
            .first()
            .ok_or(SessionError::Compile(CompileError::LayerCount(0)))?;
        let (ci, in_h, in_w) = (l0.ci, l0.in_h, l0.in_w);
        let q = {
            let host = self.host.as_ref().ok_or(SessionError::Artifact(
                RuntimeError::Missing("session built without .artifacts(...)".into()),
            ))?;
            let prologue = host.prologue.as_ref().ok_or(SessionError::Artifact(
                RuntimeError::Missing("model names no host prologue".into()),
            ))?;
            prologue.run_f32_to_i32(image, &host.input_shape)?
        };
        let input = Tensor3 { c: ci, h: in_h, w: in_w, data: q };
        let accel = self.run(&input)?;

        let last = self.model.layers.last().unwrap();
        let acts_shape =
            [1i64, last.co as i64, last.out_h() as i64, last.out_w() as i64];
        let host = self.host.as_ref().unwrap();
        let epilogue = host.epilogue.as_ref().ok_or(SessionError::Artifact(
            RuntimeError::Missing("model names no host epilogue".into()),
        ))?;
        let logits = epilogue.run_i32_to_f32(&accel.output.data, &acts_shape)?;
        Ok(HostRunOutput { logits, accel })
    }
}

/// Cycle-accurate drive: execute the loaded Pito program on the modelled
/// barrel CPU (the verification path). `fuel_report` is the session's
/// image-level budget, quoted in [`SessionError::FuelExhausted`] — under
/// multi-pass the system's own `max_cycles` is only the remaining share.
fn drive_cycle_accurate(sys: &mut System, fuel_report: u64) -> Result<(), SessionError> {
    let exit = sys.run();
    match exit {
        SystemExit::Done | SystemExit::AllExited => {}
        SystemExit::MaxCycles => {
            return Err(SessionError::FuelExhausted { fuel: fuel_report })
        }
        // A rejected or aborted launch is recorded by the bridge; prefer
        // those diagnostics over the raw trap/deadlock when present.
        SystemExit::Deadlock => {
            if !sys.launch_errors().is_empty() {
                return Err(SessionError::Launch(sys.launch_errors().to_vec()));
            }
            return Err(SessionError::Deadlock);
        }
        SystemExit::Fault { hart, trap } => {
            if !sys.launch_errors().is_empty() {
                return Err(SessionError::Launch(sys.launch_errors().to_vec()));
            }
            return Err(SessionError::Fault { hart, trap });
        }
    }
    if !sys.launch_errors().is_empty() {
        return Err(SessionError::Launch(sys.launch_errors().to_vec()));
    }
    Ok(())
}

/// Turbo drive of a pipelined pass: replay the compiled job stream through
/// the job-level executor, skipping the CPU entirely. The compiled plans
/// already encode the dataflow order the program enforces at runtime, so
/// sequential replay is exact. The fuel budget is honoured in modelled MVP
/// cycles, checked *after* every job so a stream that overshoots — even on
/// its final job — fails with [`SessionError::FuelExhausted`] just like a
/// starved cycle-accurate run (whose fuel check also fires at
/// `cycles >= max`). A malformed job surfaces as the same typed
/// [`SessionError::Launch`] the CSR bridge reports, never a panic.
fn drive_pipelined_turbo(
    sys: &mut System,
    plans: &[LayerPlan],
    fuel_report: u64,
) -> Result<(), SessionError> {
    let cap = sys.max_cycles();
    for plan in plans {
        let before = sys.mvus[plan.mvu].busy_cycles();
        // Replay the plan's memoized traces: the walk is captured once per
        // compiled plan and shared by every frame and batch item.
        for (job, trace) in plan.jobs.iter().zip(plan.traces()) {
            sys.run_job_traced(plan.mvu, job, Some(trace))
                .map_err(|e| SessionError::Launch(vec![e.to_string()]))?;
            if sys.cycles() >= cap {
                return Err(SessionError::FuelExhausted { fuel: fuel_report });
            }
        }
        // Cross-check: the job-formula cycles turbo books must equal the
        // analytic per-layer model (Table-3 exact).
        debug_assert_eq!(
            sys.mvus[plan.mvu].busy_cycles() - before,
            plan.analytic_cycles,
            "turbo cycle accounting diverged from perf model on MVU {}",
            plan.mvu
        );
    }
    Ok(())
}

/// Turbo drive of a distributed plan: independent per-MVU chunks, replayed
/// sequentially with the same fuel and launch-error contracts as
/// [`drive_pipelined_turbo`].
fn drive_distributed_turbo(
    sys: &mut System,
    plan: &DistributedPlan,
    fuel_report: u64,
) -> Result<(), SessionError> {
    let cap = sys.max_cycles();
    for (m, (chunk, traces)) in plan.jobs.iter().zip(plan.traces()).enumerate() {
        for (job, trace) in chunk.iter().zip(traces) {
            sys.run_job_traced(m, job, Some(trace))
                .map_err(|e| SessionError::Launch(vec![e.to_string()]))?;
            if sys.cycles() >= cap {
                return Err(SessionError::FuelExhausted { fuel: fuel_report });
            }
        }
    }
    Ok(())
}

/// Build the lap schedule of one pipelined pass: closed when `arrivals`
/// is `None`, continuous admission at the given arrival laps otherwise.
fn schedule_for(c: &CompiledModel, frames: usize, arrivals: Option<&[usize]>) -> StreamSchedule {
    match arrivals {
        None => StreamSchedule::new(c.stage_cycles(), frames),
        Some(laps) => {
            debug_assert_eq!(laps.len(), frames);
            let mut sched = StreamSchedule::open(c.stage_cycles());
            for &a in laps {
                sched.admit(a);
            }
            sched
        }
    }
}

/// Stream one pipelined pass over `inputs` with one frame per stage in
/// flight. The caller has reset run state, made weights resident and armed
/// `sys.max_cycles()` with the batch's remaining fuel.
///
/// Per lap `t` of the [`StreamSchedule`]: the entering frame (if any — its
/// entry lap is `t`) is DMA'd into MVU 0's buffer of the frame's parity,
/// every active stage `k` replays its job stream for the frame that
/// entered at lap `t − k` out of that frame's buffer parity via
/// [`System::run_lap`] (concurrent under both backends), and the retiring
/// frame — the one that just left the last stage — is read back from its
/// output buffer before that buffer's next reuse two frames later. Open
/// schedules interleave idle bubble laps (no work, no cost executed) when
/// the feed gaps; entries strictly increase, so buffer reuse keeps the
/// same two-frame distance as the closed batch. Returns per-frame
/// `(output, per-stage cycles)` in frame order plus the batch accounting.
fn stream_compiled(
    sys: &mut System,
    c: &CompiledModel,
    inputs: &[Tensor3],
    out_co: usize,
    fuel_report: u64,
    program_driven: bool,
    arrivals: Option<&[usize]>,
) -> Result<(FrameResults, StreamMetrics), SessionError> {
    if program_driven {
        return stream_program_exec(sys, c, inputs, out_co, fuel_report, arrivals);
    }
    let stages = c.plans.len();
    let frames = inputs.len();
    let sched = schedule_for(c, frames, arrivals);
    let cap = sys.max_cycles();
    let mut per_frame: Vec<Vec<u64>> = vec![vec![0; stages]; frames];
    let mut raw: FrameResults = Vec::with_capacity(frames);
    let mut next_in = 0usize;
    let mut measured = 0u64;
    for lap in 0..sched.laps() {
        while next_in < frames && sched.entry_lap(next_in) == lap {
            c.load_input_parity(sys, &inputs[next_in], next_in % 2);
            next_in += 1;
        }
        let active = sched.active(lap);
        let turbo = sys.exec_mode() == ExecMode::Turbo;
        let mut work: Vec<LapStream> = Vec::with_capacity(active.len());
        let mut track: Vec<(usize, usize, usize, u64)> = Vec::with_capacity(active.len());
        for &(k, f) in &active {
            let plan = c.stage_plan(k, f % 2);
            track.push((k, f, plan.mvu, sys.mvus[plan.mvu].busy_cycles()));
            work.push(LapStream {
                mvu: plan.mvu,
                jobs: plan.jobs.as_slice(),
                // Memoized traces feed the turbo replay only; capturing
                // them under the cycle-accurate backend would be pure waste.
                traces: turbo.then(|| plan.traces()),
            });
        }
        measured +=
            sys.run_lap_traced(&work).map_err(|e| SessionError::Launch(vec![e.to_string()]))?;
        if sys.cycles() >= cap {
            return Err(SessionError::FuelExhausted { fuel: fuel_report });
        }
        for (k, f, m, before) in track {
            let booked = sys.mvus[m].busy_cycles() - before;
            // Cross-check: streamed laps book exactly the analytic
            // per-layer cycles — Table-3/Table-5 accounting is invariant
            // to how many frames are in flight.
            debug_assert_eq!(booked, c.plans[k].analytic_cycles, "stage {k} frame {f}");
            per_frame[f][k] = booked;
        }
        while raw.len() < frames && sched.entry_lap(raw.len()) + stages == lap + 1 {
            let f = raw.len();
            let out = c.read_output_parity(sys, out_co, f % 2);
            raw.push((out, std::mem::take(&mut per_frame[f])));
        }
    }
    let cyc = sched.cycles();
    let stream = StreamMetrics {
        frames: frames as u64,
        stages,
        fill_cycles: cyc.fill,
        steady_cycles: cyc.steady,
        drain_cycles: cyc.drain,
        pipeline_cycles: cyc.total(),
        bottleneck_cycles: sched.bottleneck_cycles(),
        serial_cycles: sched.serial_cycles_per_frame() * frames as u64,
        measured_cycles: measured,
    };
    Ok((raw, stream))
}

/// Execute a streamed batch by running the **generated multi-frame Pito
/// program** on the modelled CPU ([`CompiledModel::stream_program`]): the
/// frames-in-flight overlap falls out of the per-row DRAM flag protocol in
/// the instruction stream, not host scheduling. The host's only runtime
/// role is the DMA the paper gives it — stage inputs into the free parity
/// buffer (bumping `HOST_IN_FLAG`), read retired outputs (bumping
/// `HOST_OUT_FLAG`) — serviced once per modelled cycle between
/// [`System::poll_step`]s.
///
/// Accounting stays bit-identical to the host-lap driver: each frame's
/// per-stage cycles book the analytic per-layer model, which is exactly
/// what the MVUs execute (`debug_assert`ed against the busy counters —
/// `frames × analytic` per stage). The [`StreamSchedule`] lap model is
/// demoted to a cross-check: the executed wall can never beat the
/// bottleneck bound. `measured_cycles` is the one path-dependent field —
/// the program-driven wall includes the CPU's launch overhead.
///
/// Continuous admission (`arrivals` present) needs **no new program
/// shape**: hart 0 already gates each frame's entry on `HOST_IN`, so the
/// host simply bumps the flag between `poll_step`s — monotone incremental
/// posting, one frame per service pass, never more than the two parity
/// buffers hold. The posting schedule is validated statically
/// ([`crate::analysis::verify_host_posting`]) before the CPU runs a
/// cycle; outputs are invariant to posting timing (the flag protocol
/// self-paces), so the arrival laps shape only the [`StreamSchedule`]
/// accounting.
fn stream_program_exec(
    sys: &mut System,
    c: &CompiledModel,
    inputs: &[Tensor3],
    out_co: usize,
    fuel_report: u64,
    arrivals: Option<&[usize]>,
) -> Result<(FrameResults, StreamMetrics), SessionError> {
    use crate::codegen::{frame_flag_addr, HOST_IN_FLAG, HOST_OUT_FLAG};
    let stages = c.plans.len();
    let frames = inputs.len();
    // The admission schedule the service loop follows: both parity
    // buffers staged up front, then one bump per observed retirement.
    // Proven against the two-frame buffer contract before any cycle.
    let posting: Vec<i32> = (frames.min(2) as i32..=frames as i32).collect();
    let report = crate::analysis::verify_host_posting(frames, &posting, VerifyLevel::Quick);
    if !report.is_clean() {
        return Err(SessionError::Verify(report.diagnostics));
    }
    let sp = c.stream_program(frames).map_err(SessionError::Compile)?;
    sys.load_program(&sp.program);
    let cycles0 = sys.cycles();
    #[cfg(debug_assertions)]
    let busy0: Vec<u64> = sys.mvus.iter().map(|m| m.busy_cycles()).collect();

    // Stage up to both parity buffers before releasing the CPU.
    let mut next_in = 0;
    while next_in < frames.min(2) {
        c.load_input_parity(sys, &inputs[next_in], next_in % 2);
        next_in += 1;
    }
    sys.cpu.write_dram(HOST_IN_FLAG, &(next_in as i32).to_le_bytes());

    let stage_book = c.stage_cycles();
    let mut raw: FrameResults = Vec::with_capacity(frames);
    sys.begin_run();
    let exit = loop {
        // Input parity `next_in % 2` is free once stage 0 has retired
        // frame `next_in − 2` (FRAMES[0] >= next_in − 1).
        if next_in < frames
            && sys.cpu.read_dram_word(frame_flag_addr(0)) as i32 >= next_in as i32 - 1
        {
            c.load_input_parity(sys, &inputs[next_in], next_in % 2);
            next_in += 1;
            sys.cpu.write_dram(HOST_IN_FLAG, &(next_in as i32).to_le_bytes());
        }
        // A frame is readable once the last stage retires it, and must be
        // read before that stage starts frame f + 2 (which reuses the
        // buffer) — the program waits on HOST_OUT for exactly that.
        if raw.len() < frames
            && sys.cpu.read_dram_word(frame_flag_addr(stages - 1)) as i32
                >= raw.len() as i32 + 1
        {
            let f = raw.len();
            let out = c.read_output_parity(sys, out_co, f % 2);
            raw.push((out, stage_book.clone()));
            sys.cpu.write_dram(HOST_OUT_FLAG, &(raw.len() as i32).to_le_bytes());
        }
        if let Some(exit) = sys.poll_step() {
            break exit;
        }
    };
    match exit {
        SystemExit::Done | SystemExit::AllExited => {}
        SystemExit::MaxCycles => return Err(SessionError::FuelExhausted { fuel: fuel_report }),
        SystemExit::Deadlock => {
            if !sys.launch_errors().is_empty() {
                return Err(SessionError::Launch(sys.launch_errors().to_vec()));
            }
            return Err(SessionError::Deadlock);
        }
        SystemExit::Fault { hart, trap } => {
            if !sys.launch_errors().is_empty() {
                return Err(SessionError::Launch(sys.launch_errors().to_vec()));
            }
            return Err(SessionError::Fault { hart, trap });
        }
    }
    if !sys.launch_errors().is_empty() {
        return Err(SessionError::Launch(sys.launch_errors().to_vec()));
    }
    // Frames that retired after the last pre-exit service pass.
    while raw.len() < frames {
        let f = raw.len();
        let out = c.read_output_parity(sys, out_co, f % 2);
        raw.push((out, stage_book.clone()));
    }
    // The program drove exactly the plans' job streams, `frames` times
    // each — same busy totals as `frames` serial runs or the lap replay.
    #[cfg(debug_assertions)]
    for plan in &c.plans {
        debug_assert_eq!(
            sys.mvus[plan.mvu].busy_cycles() - busy0[plan.mvu],
            plan.analytic_cycles * frames as u64,
            "program-driven stream booked wrong cycles on MVU {}",
            plan.mvu
        );
    }
    let measured = sys.cycles() - cycles0;
    let sched = schedule_for(c, frames, arrivals);
    // Lap-model cross-check: one frame per bottleneck lap is the floor
    // (only under the stepper — turbo completes jobs in zero wall cycles).
    if sys.exec_mode() == ExecMode::CycleAccurate {
        debug_assert!(
            measured >= sched.bottleneck_cycles().saturating_mul(frames as u64),
            "program-driven wall {measured} beats the lap-model bottleneck bound"
        );
    }
    let cyc = sched.cycles();
    let stream = StreamMetrics {
        frames: frames as u64,
        stages,
        fill_cycles: cyc.fill,
        steady_cycles: cyc.steady,
        drain_cycles: cyc.drain,
        pipeline_cycles: cyc.total(),
        bottleneck_cycles: sched.bottleneck_cycles(),
        serial_cycles: sched.serial_cycles_per_frame() * frames as u64,
        measured_cycles: measured,
    };
    Ok((raw, stream))
}

/// Stream a batch through a multi-pass program: per pass, reset run state,
/// re-arm the *remaining* batch fuel, reload that pass's weights and
/// program **once for the whole batch** (serial multi-pass pays the reload
/// per image — batching amortises the §3.1.6 lap cost by the batch size),
/// then stream every frame through the pass's ≤8 stages, carrying each
/// frame's output tensor to the next pass. Accounting sums the per-pass
/// fill/steady/drain model; per-frame layer cycles concatenate across
/// passes in model order. Continuous admission applies to pass 0 only —
/// by the time a later pass starts, every carried frame is on hand, so
/// the remaining passes stream dense closed batches.
fn stream_multi_pass(
    sys: &mut System,
    plan: &MultiPassPlan,
    model: &Model,
    inputs: &[Tensor3],
    fuel_report: u64,
    program_driven: bool,
    arrivals: Option<&[usize]>,
) -> Result<(FrameResults, StreamMetrics), SessionError> {
    let frames = inputs.len();
    let cap = fuel_report.saturating_mul(frames as u64);
    let mut spent = 0u64;
    let mut carried: Vec<Tensor3> = inputs.to_vec();
    let mut layer_cycles: Vec<Vec<u64>> = vec![Vec::new(); frames];
    let mut agg = StreamMetrics { frames: frames as u64, ..Default::default() };
    for (p, pass) in plan.passes.iter().enumerate() {
        if spent >= cap {
            return Err(SessionError::FuelExhausted { fuel: fuel_report });
        }
        sys.reset_run_state();
        sys.set_max_cycles(cap - spent);
        pass.load_weights(sys);
        let (_, end) = plan.ranges[p];
        let co = model.layers[end - 1].co;
        let pass_arrivals = if p == 0 { arrivals } else { None };
        let (outs, s) =
            stream_compiled(sys, pass, &carried, co, fuel_report, program_driven, pass_arrivals)?;
        spent += sys.cycles();
        agg.stages = agg.stages.max(s.stages);
        agg.fill_cycles += s.fill_cycles;
        agg.steady_cycles += s.steady_cycles;
        agg.drain_cycles += s.drain_cycles;
        agg.pipeline_cycles += s.pipeline_cycles;
        agg.bottleneck_cycles += s.bottleneck_cycles;
        agg.serial_cycles += s.serial_cycles;
        agg.measured_cycles += s.measured_cycles;
        carried = Vec::with_capacity(frames);
        for (f, (out, cycles)) in outs.into_iter().enumerate() {
            layer_cycles[f].extend(cycles);
            carried.push(out);
        }
    }
    Ok((carried.into_iter().zip(layer_cycles).collect(), agg))
}

/// A session slots straight into the serving coordinator: one engine per
/// worker thread, each owning its own warm system (PJRT executables are
/// thread-affine, so sessions are built inside the worker's
/// `EngineFactory`).
impl Engine for InferenceSession {
    fn infer_batch(&mut self, images: &[Vec<f32>]) -> Vec<Result<(Vec<f32>, u64), String>> {
        images
            .iter()
            .map(|img| {
                // A failed image is a per-request typed error, not a panic:
                // a poisoned request must not tear down the worker thread
                // (and with it every queued request on this engine).
                self.run_image(img)
                    .map(|out| (out.logits, out.accel.total_mvu_cycles))
                    .map_err(|e| e.to_string())
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::SystemConfig;
    use crate::model::zoo::{resnet9_cifar10, Rng};
    use crate::quant::QuantSerCfg;
    use crate::sim::{conv2d_i32, requant_i32};

    fn golden_forward(model: &Model, input: &Tensor3) -> Tensor3 {
        model.golden_forward(input)
    }

    /// First six ResNet9 layers at 16×16 — fast enough for debug-mode unit
    /// tests while still exercising the full pipelined chain.
    fn tiny_resnet9() -> Model {
        let mut m = resnet9_cifar10(2, 2);
        m.layers.truncate(6);
        let mut h = 16;
        for l in &mut m.layers {
            l.in_h = h;
            l.in_w = h;
            if l.stride == 2 {
                h /= 2;
            }
        }
        m.validate().unwrap();
        m
    }

    fn random_input(m: &Model, seed: u64) -> Tensor3 {
        let l0 = &m.layers[0];
        let mut rng = Rng(seed);
        Tensor3::from_fn(l0.ci, l0.in_h, l0.in_w, |_, _, _| {
            rng.range_i32(0, l0.aprec.max_value())
        })
    }

    /// The headline property: a warm (turbo, by default) session serving N
    /// images is bit-exact with building a fresh cycle-accurate system per
    /// image.
    #[test]
    fn warm_session_matches_fresh_system_per_image() {
        let m = tiny_resnet9();
        let mut session = SessionBuilder::new(m.clone()).build().unwrap();
        assert_eq!(session.exec_mode(), ExecMode::Turbo, "turbo is the run() default");
        let compiled = compile_pipelined(&m, EdgePolicy::PadInRam).unwrap();
        for seed in [1u64, 2, 3, 4] {
            let input = random_input(&m, seed);
            let warm = session.run(&input).unwrap();
            // Fresh per-image rebuild (the old cold path).
            let mut sys = System::new(SystemConfig::default());
            compiled.load_into(&mut sys, &input);
            assert_eq!(sys.run(), SystemExit::AllExited);
            let cold = compiled.read_output(&sys, m.layers.last().unwrap().co);
            assert_eq!(warm.output, cold, "seed {seed}: warm != cold");
            assert_eq!(warm.output, golden_forward(&m, &input), "seed {seed}: != golden");
            assert_eq!(warm.total_mvu_cycles, sys.total_mvu_busy_cycles(), "seed {seed}");
        }
        let metrics = session.metrics();
        assert_eq!(metrics.images, 4);
        assert_eq!(metrics.total_mvu_cycles, metrics.mean_mvu_cycles() * 4);
        // The bottleneck stage is at most the whole array's work and the
        // FPS estimate is finite and positive.
        assert!(metrics.total_bottleneck_cycles > 0);
        assert!(metrics.total_bottleneck_cycles <= metrics.total_mvu_cycles);
        assert!(metrics.serial_fps_at(crate::CLOCK_HZ) > 0.0);
        // The serial rate can never beat the steady-state lap bound.
        assert!(
            metrics.serial_fps_at(crate::CLOCK_HZ)
                <= metrics.steady_state_fps_bound_at(crate::CLOCK_HZ)
        );
        // Nothing streamed yet: the streamed rate reports 0.
        assert_eq!(metrics.streamed_images, 0);
        assert_eq!(metrics.streamed_fps_at(crate::CLOCK_HZ), 0.0);
    }

    #[test]
    fn image_indices_increment() {
        let m = tiny_resnet9();
        let mut session = SessionBuilder::new(m.clone()).build().unwrap();
        let input = random_input(&m, 9);
        assert_eq!(session.run(&input).unwrap().image_index, 0);
        assert_eq!(session.run(&input).unwrap().image_index, 1);
    }

    /// Backend equivalence through the session facade: turbo and
    /// cycle-accurate runs of the same warm session report identical
    /// outputs and per-MVU job cycles (system cycles legitimately differ —
    /// only the timing backend models CPU orchestration).
    #[test]
    fn session_backends_agree_bit_for_bit() {
        let m = tiny_resnet9();
        let mut turbo = SessionBuilder::new(m.clone())
            .exec_mode(ExecMode::Turbo)
            .build()
            .unwrap();
        let mut cycle = SessionBuilder::new(m.clone())
            .exec_mode(ExecMode::CycleAccurate)
            .build()
            .unwrap();
        for seed in [5u64, 6] {
            let input = random_input(&m, seed);
            let t = turbo.run(&input).unwrap();
            let c = cycle.run(&input).unwrap();
            assert_eq!(t.exec, ExecMode::Turbo);
            assert_eq!(c.exec, ExecMode::CycleAccurate);
            assert_eq!(t.output, c.output, "seed {seed}: outputs differ");
            assert_eq!(t.mvu_cycles, c.mvu_cycles, "seed {seed}: job cycles differ");
            // Turbo's global clock advances by MVP job cycles only (the
            // exact sum of every job formula); no CPU cycles appear in it.
            assert_eq!(t.system_cycles, t.total_mvu_cycles, "seed {seed}");
        }
    }

    #[test]
    fn tiny_fuel_yields_fuel_exhausted() {
        let m = tiny_resnet9();
        let mut session = SessionBuilder::new(m.clone()).fuel(500).build().unwrap();
        let err = session.run(&random_input(&m, 3)).unwrap_err();
        assert_eq!(err, SessionError::FuelExhausted { fuel: 500 });
        // The session stays usable: bump nothing, just observe the typed
        // error is stable across calls.
        assert!(matches!(
            session.run(&random_input(&m, 4)),
            Err(SessionError::FuelExhausted { fuel: 500 })
        ));
    }

    #[test]
    fn malformed_model_yields_compile_error() {
        let mut m = tiny_resnet9();
        m.layers[1].ci = 100; // breaks the channel chain
        match SessionBuilder::new(m).build() {
            Err(SessionError::Compile(CompileError::InvalidModel(_))) => {}
            other => panic!("expected Compile(InvalidModel), got {:?}", other.err()),
        }
    }

    #[test]
    fn empty_model_yields_layer_count_error() {
        let m = Model {
            name: "empty".into(),
            layers: vec![],
            host_prologue: None,
            host_epilogue: None,
        };
        match SessionBuilder::new(m).build() {
            Err(SessionError::Compile(CompileError::LayerCount(0))) => {}
            other => panic!("expected Compile(LayerCount(0)), got {:?}", other.err()),
        }
    }

    #[test]
    fn distributed_mode_requires_single_layer() {
        let m = tiny_resnet9();
        match SessionBuilder::new(m).mode(ExecutionMode::Distributed).build() {
            Err(SessionError::Compile(CompileError::Mode(_))) => {}
            other => panic!("expected Compile(Mode), got {:?}", other.err()),
        }
    }

    /// Distributed sessions reuse weights across images too.
    #[test]
    fn distributed_session_matches_golden() {
        let full = resnet9_cifar10(2, 2);
        let mut layer = full.layers[5].clone(); // 256→256
        layer.in_h = 8;
        layer.in_w = 8;
        let single = Model {
            name: "one-layer".into(),
            layers: vec![layer.clone()],
            host_prologue: None,
            host_epilogue: None,
        };
        let mut session = SessionBuilder::new(single)
            .mode(ExecutionMode::Distributed)
            .build()
            .unwrap();
        for seed in [11u64, 12] {
            let mut rng = Rng(seed);
            let input = Tensor3::from_fn(layer.ci, layer.in_h, layer.in_w, |_, _, _| {
                rng.range_i32(0, 3)
            });
            let got = session.run(&input).unwrap().output;
            let acc = conv2d_i32(&input, &layer.weights, layer.spec());
            let want = requant_i32(
                &acc,
                &layer.quant.scale,
                &layer.quant.bias,
                QuantSerCfg {
                    msb_index: layer.quant.quant_msb,
                    out_bits: layer.oprec.bits,
                    saturate: true,
                },
                layer.relu,
            );
            assert_eq!(got, want, "seed {seed}");
        }
    }

    /// A deep (>8-layer) chain of small 64-channel conv layers — fast
    /// enough for debug-mode unit tests while forcing ≥2 scheduling
    /// passes.
    fn tiny_deep_model(depth: usize) -> Model {
        use crate::model::{ConvLayer, QuantSpec};
        use crate::quant::Precision;
        let mut rng = Rng(0xD0_0D);
        let aprec = Precision::u(2);
        let wprec = Precision::s(2);
        let max_acc = (64 * 9) as i64 * 3 * 2;
        let msb = 63 - ((max_acc * 4) as u64).leading_zeros() as u8;
        let layers = (0..depth)
            .map(|i| ConvLayer {
                name: format!("deep{i}"),
                ci: 64,
                co: 64,
                fh: 3,
                fw: 3,
                stride: 1,
                pad: 1,
                in_h: 8,
                in_w: 8,
                aprec,
                wprec,
                oprec: aprec,
                relu: true,
                weights: (0..64 * 64 * 9).map(|_| rng.range_i32(-2, 1)).collect(),
                quant: QuantSpec {
                    scale: (0..64).map(|_| rng.range_i32(1, 4) as u16).collect(),
                    bias: (0..64).map(|_| rng.range_i32(-64, 64)).collect(),
                    quant_msb: msb,
                },
            })
            .collect();
        let m = Model {
            name: format!("tiny-deep-{depth}"),
            layers,
            host_prologue: None,
            host_epilogue: None,
        };
        m.validate().unwrap();
        m
    }

    #[test]
    fn auto_mode_resolves_by_depth() {
        let s = SessionBuilder::new(tiny_deep_model(1))
            .mode(ExecutionMode::Auto)
            .build()
            .unwrap();
        assert_eq!(s.execution_mode(), ExecutionMode::Distributed);
        assert_eq!(s.n_passes(), 1);

        let s = SessionBuilder::new(tiny_resnet9())
            .mode(ExecutionMode::Auto)
            .build()
            .unwrap();
        assert_eq!(s.execution_mode(), ExecutionMode::Pipelined);
        assert_eq!(s.n_passes(), 1);

        let s = SessionBuilder::new(tiny_deep_model(10))
            .mode(ExecutionMode::Auto)
            .build()
            .unwrap();
        assert_eq!(s.execution_mode(), ExecutionMode::MultiPass);
        assert_eq!(s.n_passes(), 2);
        assert!(s.program_len() > 0);
        assert!(s.asm().contains("pass1"), "multi-pass asm lists every pass");
    }

    #[test]
    fn mode_parsing_and_display() {
        for (s, m) in [
            ("pipelined", ExecutionMode::Pipelined),
            ("distributed", ExecutionMode::Distributed),
            ("multipass", ExecutionMode::MultiPass),
            ("multi-pass", ExecutionMode::MultiPass),
            ("auto", ExecutionMode::Auto),
        ] {
            assert_eq!(s.parse::<ExecutionMode>().unwrap(), m);
        }
        assert!("warp".parse::<ExecutionMode>().is_err());
        assert_eq!(ExecutionMode::MultiPass.to_string(), "multi-pass");
        let args = |s: &[&str]| -> Vec<String> { s.iter().map(|a| a.to_string()).collect() };
        assert_eq!(
            parse_mode_arg(&args(&["--images", "2"]), ExecutionMode::Auto),
            Ok(ExecutionMode::Auto)
        );
        assert_eq!(
            parse_mode_arg(&args(&["--mode", "multipass"]), ExecutionMode::Auto),
            Ok(ExecutionMode::MultiPass)
        );
        assert!(parse_mode_arg(&args(&["--mode"]), ExecutionMode::Auto).is_err());
        assert!(parse_mode_arg(&args(&["--mode", "warp"]), ExecutionMode::Auto).is_err());
    }

    /// The tentpole acceptance property at unit scale: a 10-layer model
    /// (two passes) is bit-exact with the golden integer model under both
    /// execution backends, per-layer cycle accounting matches the analytic
    /// formula, and the session stays warm across images.
    #[test]
    fn multi_pass_deep_session_matches_golden_both_backends() {
        let m = tiny_deep_model(10);
        let input = random_input(&m, 77);
        let golden = golden_forward(&m, &input);
        let analytic: u64 = m
            .layers
            .iter()
            .map(|l| crate::codegen::layer_cycles(l, EdgePolicy::PadInRam))
            .sum();
        for exec in [ExecMode::Turbo, ExecMode::CycleAccurate] {
            let mut session = SessionBuilder::new(m.clone())
                .mode(ExecutionMode::Auto)
                .exec_mode(exec)
                .build()
                .unwrap();
            let out = session.run(&input).unwrap();
            assert_eq!(out.output, golden, "{exec:?}: output != golden");
            assert_eq!(out.mvu_cycles.len(), m.layers.len(), "{exec:?}: per-layer cycles");
            for (i, (l, &c)) in m.layers.iter().zip(&out.mvu_cycles).enumerate() {
                assert_eq!(
                    c,
                    crate::codegen::layer_cycles(l, EdgePolicy::PadInRam),
                    "{exec:?}: layer {i}"
                );
            }
            assert_eq!(out.total_mvu_cycles, analytic, "{exec:?}");
            // Warm reuse: pass-rotating weight reloads must not corrupt
            // the second image.
            let out2 = session.run(&input).unwrap();
            assert_eq!(out2.output, golden, "{exec:?}: second image differs");
            assert_eq!(out2.image_index, 1);
            let metrics = session.metrics();
            assert_eq!(metrics.images, 2);
            // Per-pass bottleneck sum: ≤ total, ≥ total / 8.
            assert!(metrics.total_bottleneck_cycles <= metrics.total_mvu_cycles);
            assert!(metrics.total_bottleneck_cycles * 8 >= metrics.total_mvu_cycles);
        }
    }

    /// Fuel is an image budget honoured *across* passes: a budget that
    /// covers pass 0 but not the full image exhausts on a later pass.
    #[test]
    fn multi_pass_fuel_spans_passes() {
        let m = tiny_deep_model(10);
        let per_layer = crate::codegen::layer_cycles(&m.layers[0], EdgePolicy::PadInRam);
        let total = per_layer * 10;
        let input = random_input(&m, 5);

        // Turbo books exactly the MVP cycles: 9 layers' worth covers all of
        // pass 0 (8 layers) but exhausts inside pass 1.
        let fuel = per_layer * 9;
        let mut starved = SessionBuilder::new(m.clone())
            .mode(ExecutionMode::MultiPass)
            .fuel(fuel)
            .build()
            .unwrap();
        match starved.run(&input) {
            Err(SessionError::FuelExhausted { fuel: f }) => assert_eq!(f, fuel),
            other => panic!("expected FuelExhausted, got {:?}", other.map(|o| o.image_index)),
        }

        // A budget above the whole image succeeds.
        let mut fed = SessionBuilder::new(m)
            .mode(ExecutionMode::MultiPass)
            .fuel(total + 1)
            .build()
            .unwrap();
        let out = fed.run(&input).unwrap();
        assert_eq!(out.total_mvu_cycles, total);
        assert_eq!(out.system_cycles, total, "turbo clock sums MVP cycles over passes");
    }

    /// Regression: a weight image larger than the configured weight RAM is
    /// a typed build-time error, not a slice-out-of-range panic at load
    /// time (4-bit weights push the deep model's 512-channel layers to
    /// 2304 words against the stock 2048-word RAM).
    #[test]
    fn oversized_weight_image_yields_typed_capacity_error() {
        let m = crate::model::zoo::resnet18_cifar(2, 4);
        match SessionBuilder::new(m.clone()).mode(ExecutionMode::Auto).build() {
            Err(SessionError::Compile(CompileError::CapacityExceeded {
                resource: "weight",
                ..
            })) => {}
            other => panic!(
                "expected CapacityExceeded, got {:?}",
                other.err().map(|e| e.to_string())
            ),
        }
        // A deeper weight RAM (a build parameter, §3.1.2) accepts it.
        let cfg = crate::mvu::MvuConfig { weight_depth: 4096, ..Default::default() };
        SessionBuilder::new(m)
            .mode(ExecutionMode::Auto)
            .mvu_config(cfg)
            .build()
            .unwrap();
    }

    /// Cache-accounting contract: single-pass sessions report their
    /// build-time resident words (what a fleet cache hit saves); multi-pass
    /// sessions report 0 resident (weights rotate per image regardless of
    /// warmth) with the rotation cost on `per_image_reload_words`.
    #[test]
    fn resident_words_split_build_time_from_per_image() {
        let single = SessionBuilder::new(tiny_resnet9()).build().unwrap();
        assert!(single.resident_words() > 0);
        assert_eq!(single.per_image_reload_words(), 0);

        let multi = SessionBuilder::new(tiny_deep_model(10))
            .mode(ExecutionMode::MultiPass)
            .build()
            .unwrap();
        assert_eq!(multi.resident_words(), 0);
        assert!(multi.per_image_reload_words() > 0);
    }

    #[test]
    fn run_image_without_artifacts_is_typed() {
        let m = tiny_resnet9();
        let mut session = SessionBuilder::new(m).build().unwrap();
        match session.run_image(&[0.0; 4]) {
            Err(SessionError::Artifact(RuntimeError::Missing(_))) => {}
            other => panic!("expected Artifact(Missing), got {:?}", other.err()),
        }
    }

    /// The tentpole property at unit scale: a streamed batch (frames in
    /// flight across the MVU stages, double-buffered regions) is
    /// bit-identical to serial `run` per frame — outputs *and* per-layer
    /// cycle accounting — under both execution backends, while the batch
    /// wall model beats serial execution.
    #[test]
    fn streamed_batch_matches_serial_bit_for_bit() {
        let m = tiny_resnet9();
        for exec in [ExecMode::Turbo, ExecMode::CycleAccurate] {
            let mut serial = SessionBuilder::new(m.clone()).exec_mode(exec).build().unwrap();
            let mut streamed = SessionBuilder::new(m.clone()).exec_mode(exec).build().unwrap();
            let inputs: Vec<Tensor3> = (0..4).map(|s| random_input(&m, 100 + s)).collect();
            let batch = streamed.run_stream(&inputs).unwrap();
            assert_eq!(batch.outputs.len(), 4);
            for (i, input) in inputs.iter().enumerate() {
                let want = serial.run(input).unwrap();
                let got = &batch.outputs[i];
                assert_eq!(got.output, want.output, "{exec:?}: frame {i} output");
                assert_eq!(got.mvu_cycles, want.mvu_cycles, "{exec:?}: frame {i} cycles");
                assert_eq!(got.image_index, i as u64, "{exec:?}");
            }
            let s = &batch.stream;
            assert_eq!(s.frames, 4);
            assert_eq!(s.stages, m.layers.len());
            assert_eq!(s.pipeline_cycles, s.fill_cycles + s.steady_cycles + s.drain_cycles);
            assert!(s.bottleneck_cycles * 4 <= s.serial_cycles);
            assert!(s.speedup() > 1.5, "{exec:?}: speedup {}", s.speedup());
            assert!(s.occupancy() > 0.0 && s.occupancy() <= 1.0, "{exec:?}");
            match exec {
                // Turbo laps advance the clock by exactly the modelled
                // pipeline; the stepper adds short crossbar-drain tails.
                ExecMode::Turbo => assert_eq!(s.measured_cycles, s.pipeline_cycles),
                ExecMode::CycleAccurate => assert!(s.measured_cycles >= s.pipeline_cycles),
            }
            let metrics = streamed.metrics();
            assert_eq!(metrics.images, 4);
            assert_eq!(metrics.streamed_images, 4);
            assert_eq!(metrics.total_pipeline_cycles, s.pipeline_cycles);
            // streamed sits strictly between achieved-serial and the
            // steady-state bound.
            let hz = crate::CLOCK_HZ;
            assert!(metrics.streamed_fps_at(hz) > metrics.serial_fps_at(hz), "{exec:?}");
            assert!(
                metrics.streamed_fps_at(hz) <= metrics.steady_state_fps_bound_at(hz),
                "{exec:?}"
            );
        }
    }

    /// Streaming a deep model: frames stream within each pass, outputs and
    /// per-layer cycles stay bit-identical to serial multi-pass runs.
    #[test]
    fn streamed_multi_pass_matches_serial() {
        let m = tiny_deep_model(10);
        for exec in [ExecMode::Turbo, ExecMode::CycleAccurate] {
            let mut serial = SessionBuilder::new(m.clone())
                .mode(ExecutionMode::MultiPass)
                .exec_mode(exec)
                .build()
                .unwrap();
            let mut streamed = SessionBuilder::new(m.clone())
                .mode(ExecutionMode::MultiPass)
                .exec_mode(exec)
                .build()
                .unwrap();
            let inputs: Vec<Tensor3> = (0..3).map(|s| random_input(&m, 40 + s)).collect();
            let batch = streamed.run_stream(&inputs).unwrap();
            for (i, input) in inputs.iter().enumerate() {
                let want = serial.run(input).unwrap();
                let got = &batch.outputs[i];
                assert_eq!(got.output, want.output, "{exec:?}: frame {i}");
                assert_eq!(got.mvu_cycles, want.mvu_cycles, "{exec:?}: frame {i}");
                assert_eq!(got.mvu_cycles.len(), m.layers.len(), "{exec:?}: per *layer*");
            }
            let s = &batch.stream;
            assert_eq!(s.frames, 3);
            assert_eq!(s.stages, crate::NUM_MVUS, "widest pass");
            // Two passes: the per-frame steady-state cost sums both
            // pass bottlenecks — the streamed version of the lap model.
            let per_layer = crate::codegen::layer_cycles(&m.layers[0], EdgePolicy::PadInRam);
            assert_eq!(s.bottleneck_cycles, 2 * per_layer, "uniform layers: one per pass");
            assert!(s.speedup() > 1.0, "{exec:?}: {}", s.speedup());
        }
    }

    /// The two streamed engines are interchangeable: the generated
    /// multi-frame program executed on the modelled CPU
    /// (`StreamDriver::Program`, the cycle-accurate default) produces the
    /// same outputs, per-frame cycle books, stream accounting and final
    /// activation-RAM contents as the host-driven lap replay
    /// (`StreamDriver::HostLaps` forced onto the same backend). Only
    /// `measured_cycles` may differ — the program-driven wall includes the
    /// CPU's flag-spin and launch overhead.
    #[test]
    fn stream_driver_program_matches_host_laps() {
        let m = tiny_resnet9();
        let mut prog = SessionBuilder::new(m.clone())
            .exec_mode(ExecMode::CycleAccurate)
            .stream_driver(StreamDriver::Program)
            .build()
            .unwrap();
        let mut host = SessionBuilder::new(m.clone())
            .exec_mode(ExecMode::CycleAccurate)
            .stream_driver(StreamDriver::HostLaps)
            .build()
            .unwrap();
        let inputs: Vec<Tensor3> = (0..3).map(|s| random_input(&m, 70 + s)).collect();
        let a = prog.run_stream(&inputs).unwrap();
        let b = host.run_stream(&inputs).unwrap();
        assert_eq!(a.outputs.len(), b.outputs.len());
        for (i, (x, y)) in a.outputs.iter().zip(&b.outputs).enumerate() {
            assert_eq!(x.output, y.output, "frame {i} output");
            assert_eq!(x.mvu_cycles, y.mvu_cycles, "frame {i} cycle book");
            assert_eq!(x.output, golden_forward(&m, &inputs[i]), "frame {i} golden");
        }
        let (s, t) = (&a.stream, &b.stream);
        assert_eq!(s.frames, t.frames);
        assert_eq!(s.stages, t.stages);
        assert_eq!(s.fill_cycles, t.fill_cycles);
        assert_eq!(s.steady_cycles, t.steady_cycles);
        assert_eq!(s.drain_cycles, t.drain_cycles);
        assert_eq!(s.pipeline_cycles, t.pipeline_cycles);
        assert_eq!(s.bottleneck_cycles, t.bottleneck_cycles);
        assert_eq!(s.serial_cycles, t.serial_cycles);
        assert!(s.measured_cycles >= s.pipeline_cycles, "wall below the lap model");
        // The engines leave every activation RAM word-for-word identical —
        // same double-buffer parity discipline, down to the last frame's
        // residue.
        for (h, (pm, hm)) in prog.sys.mvus.iter().zip(&host.sys.mvus).enumerate() {
            assert_eq!(pm.act.depth(), hm.act.depth());
            for addr in 0..pm.act.depth() as u32 {
                assert_eq!(pm.act.read(addr), hm.act.read(addr), "mvu {h} act word {addr}");
            }
        }
    }

    /// A program-driven streamed batch leaves the multi-frame program
    /// resident in IRAM; interleaved serial `run()`s must transparently
    /// restore the serial program (and vice versa).
    #[test]
    fn serial_runs_interleave_with_program_driven_streams() {
        let m = tiny_resnet9();
        let mut session = SessionBuilder::new(m.clone())
            .exec_mode(ExecMode::CycleAccurate)
            .stream_driver(StreamDriver::Program)
            .build()
            .unwrap();
        let inputs: Vec<Tensor3> = (0..2).map(|s| random_input(&m, 80 + s)).collect();
        let batch = session.run_stream(&inputs).unwrap();
        assert_eq!(batch.outputs[1].output, golden_forward(&m, &inputs[1]));
        let input = random_input(&m, 90);
        let serial = session.run(&input).unwrap();
        assert_eq!(serial.output, golden_forward(&m, &input), "serial after stream");
        let batch2 = session.run_stream(&inputs).unwrap();
        assert_eq!(
            batch2.outputs[0].output,
            golden_forward(&m, &inputs[0]),
            "stream after serial"
        );
    }

    /// Streamed fuel is a batch budget (`fuel × frames`), honoured across
    /// laps and passes with the usual typed error.
    #[test]
    fn streamed_fuel_exhausts_typed() {
        let m = tiny_resnet9();
        let inputs: Vec<Tensor3> = (0..3).map(|s| random_input(&m, s as u64)).collect();
        let mut starved = SessionBuilder::new(m.clone()).fuel(500).build().unwrap();
        assert_eq!(
            starved.run_stream(&inputs).unwrap_err(),
            SessionError::FuelExhausted { fuel: 500 }
        );
        // A budget that covers the whole batch succeeds.
        let per_image: u64 = m
            .layers
            .iter()
            .map(|l| crate::codegen::layer_cycles(l, EdgePolicy::PadInRam))
            .sum();
        let mut fed = SessionBuilder::new(m).fuel(per_image + 1).build().unwrap();
        assert_eq!(fed.run_stream(&inputs).unwrap().outputs.len(), 3);
    }

    /// Streaming needs double the activation footprint: a geometry where
    /// the model runs serially but cannot double-buffer yields a typed
    /// capacity error from `run_stream`, and serial `run` keeps working.
    #[test]
    fn streamed_capacity_checked_lazily() {
        let m = tiny_resnet9();
        let c = compile_pipelined(&m, EdgePolicy::PadInRam).unwrap();
        let need = |plans: &[LayerPlan]| -> usize {
            plans
                .iter()
                .map(|p| {
                    let a = p.in_layout.base + p.in_layout.size_words();
                    let b = p.out_layout.base + p.out_layout.size_words();
                    a.max(b) as usize
                })
                .max()
                .unwrap()
        };
        let serial_need = need(&c.plans);
        let stream_need = need(&c.stream_plans);
        assert!(stream_need > serial_need, "double buffering must cost more");
        let cfg = crate::mvu::MvuConfig { act_depth: stream_need - 1, ..Default::default() };
        let mut session = SessionBuilder::new(m.clone()).mvu_config(cfg).build().unwrap();
        let input = random_input(&m, 1);
        session.run(&input).unwrap();
        match session.run_stream(std::slice::from_ref(&input)) {
            Err(SessionError::Compile(CompileError::CapacityExceeded {
                resource: "activation",
                ..
            })) => {}
            other => panic!(
                "expected activation CapacityExceeded, got {:?}",
                other.err().map(|e| e.to_string())
            ),
        }
        // The session survives the rejected stream.
        session.run(&input).unwrap();
    }

    /// Degenerate batches: empty input is a no-op; a single frame streams
    /// with pipeline == serial-shaped fill/drain accounting but identical
    /// output; distributed sessions fall back to the serial loop.
    #[test]
    fn streamed_edge_cases() {
        let m = tiny_resnet9();
        let mut session = SessionBuilder::new(m.clone()).build().unwrap();
        let empty = session.run_stream(&[]).unwrap();
        assert!(empty.outputs.is_empty());
        assert_eq!(empty.stream, StreamMetrics::default());
        assert_eq!(session.metrics().images, 0);

        let input = random_input(&m, 9);
        let one = session.run_stream(std::slice::from_ref(&input)).unwrap();
        assert_eq!(one.outputs.len(), 1);
        assert_eq!(one.stream.pipeline_cycles, one.stream.serial_cycles);
        let mut serial = SessionBuilder::new(m.clone()).build().unwrap();
        assert_eq!(one.outputs[0].output, serial.run(&input).unwrap().output);
        // Indices continue across run() and run_stream() interleavings.
        let next = session.run(&input).unwrap();
        assert_eq!(next.image_index, 1);

        // Distributed: serial fallback, honest degenerate accounting.
        let full = resnet9_cifar10(2, 2);
        let mut layer = full.layers[5].clone();
        layer.in_h = 8;
        layer.in_w = 8;
        let single = Model {
            name: "one-layer".into(),
            layers: vec![layer.clone()],
            host_prologue: None,
            host_epilogue: None,
        };
        let mut dist = SessionBuilder::new(single)
            .mode(ExecutionMode::Distributed)
            .build()
            .unwrap();
        let mut rng = Rng(11);
        let din = Tensor3::from_fn(layer.ci, layer.in_h, layer.in_w, |_, _, _| {
            rng.range_i32(0, 3)
        });
        let batch = dist.run_stream(&[din.clone(), din.clone()]).unwrap();
        assert_eq!(batch.outputs.len(), 2);
        assert_eq!(batch.stream.stages, 1);
        assert_eq!(batch.stream.pipeline_cycles, batch.stream.serial_cycles);
        assert!((batch.stream.speedup() - 1.0).abs() < 1e-12);
        assert_eq!(dist.metrics().streamed_images, 0, "fallback books no streamed frames");
    }

    /// Every variant is constructible and displays a readable message.
    #[test]
    fn error_variants_display() {
        let variants: Vec<SessionError> = vec![
            SessionError::Compile(CompileError::LayerCount(9)),
            SessionError::Fault { hart: 3, trap: Trap::IllegalInstr(0) },
            SessionError::Deadlock,
            SessionError::FuelExhausted { fuel: 42 },
            SessionError::Launch(vec!["hart 0: bad job".into()]),
            SessionError::Artifact(RuntimeError::Disabled),
        ];
        for v in variants {
            assert!(!v.to_string().is_empty(), "{v:?}");
        }
    }
}
