//! Combined MaxPool/ReLU unit (§3.1.4): "implemented as a comparator with an
//! internal register. For ReLU, the incoming value is checked against the
//! register initially set to 0. The combined MaxPool/ReLU is implemented by
//! programming MVUs to produce data in the sequence needed for a MaxPool
//! window."
//!
//! The unit consumes MVP output vectors one at a time; every `window`
//! vectors it emits the lane-wise running maximum. With ReLU enabled the
//! comparator register starts at 0 instead of −∞, which simultaneously
//! implements `max(0, ·)`.

/// 64-lane pool/ReLU comparator state.
#[derive(Debug, Clone)]
pub struct PoolRelu {
    relu: bool,
    window: u32,
    regs: [i32; 64],
    filled: u32,
}

impl PoolRelu {
    pub fn new(relu: bool, window: u32) -> Self {
        assert!(window >= 1);
        let mut p = PoolRelu { relu, window, regs: [0; 64], filled: 0 };
        p.reset_regs();
        p
    }

    fn reset_regs(&mut self) {
        let init = if self.relu { 0 } else { i32::MIN };
        self.regs = [init; 64];
        self.filled = 0;
    }

    /// Push one vector; returns the reduced vector when the window fills.
    pub fn push(&mut self, v: &[i32; 64]) -> Option<[i32; 64]> {
        for l in 0..64 {
            if v[l] > self.regs[l] {
                self.regs[l] = v[l];
            }
        }
        self.filled += 1;
        if self.filled == self.window {
            let out = self.regs;
            self.reset_regs();
            Some(out)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_only_window1() {
        let mut p = PoolRelu::new(true, 1);
        let v: [i32; 64] = std::array::from_fn(|i| i as i32 - 32);
        let out = p.push(&v).expect("window of 1 emits immediately");
        for (i, &o) in out.iter().enumerate() {
            assert_eq!(o, (i as i32 - 32).max(0));
        }
    }

    #[test]
    fn maxpool_window4() {
        let mut p = PoolRelu::new(false, 4);
        for step in 0..4 {
            let v: [i32; 64] = std::array::from_fn(|l| ((l as i32) * 10 + step) - 100);
            let r = p.push(&v);
            if step < 3 {
                assert!(r.is_none());
            } else {
                let out = r.unwrap();
                // Max over step = value at step 3, negatives preserved
                // (no ReLU).
                assert_eq!(out[0], -97);
                assert_eq!(out[63], 533);
            }
        }
    }

    #[test]
    fn maxpool_with_relu_clamps_negative_windows() {
        let mut p = PoolRelu::new(true, 2);
        assert!(p.push(&[-5; 64]).is_none());
        let out = p.push(&[-3; 64]).unwrap();
        assert_eq!(out[0], 0, "all-negative window clamps to 0 with ReLU");
    }

    #[test]
    fn window_resets_between_groups() {
        let mut p = PoolRelu::new(false, 2);
        p.push(&[100; 64]);
        let a = p.push(&[1; 64]).unwrap();
        assert_eq!(a[0], 100);
        p.push(&[2; 64]);
        let b = p.push(&[3; 64]).unwrap();
        assert_eq!(b[0], 3, "previous window's max must not leak");
    }
}
