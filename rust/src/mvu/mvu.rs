//! The streaming MVU engine: per-cycle execution of CSR-programmed jobs.
//!
//! One call to [`Mvu::step`] models one clock cycle of the MVP and its
//! downstream pipeline. The MVP is fully pipelined in hardware; here the
//! post-MVP stages (scaler → bias → pool/ReLU → QuantSer) are applied at
//! output-vector boundaries, which preserves both the numerics and the
//! cycle count (the pipeline adds fixed latency, not throughput).

use crate::quant::BLOCK;

use super::job::JobConfig;
use super::ram::{ActRam, BiasRam, ScalerRam, WeightRam};
use super::walk::{JobWalk, OutputStage};

/// Static MVU memory geometry. Defaults sized like the paper's U250 build
/// (1 MiB weight RAM, 256 KiB activation RAM per MVU).
#[derive(Debug, Clone, Copy)]
pub struct MvuConfig {
    pub act_depth: usize,
    pub weight_depth: usize,
    pub scaler_depth: usize,
    pub bias_depth: usize,
}

impl Default for MvuConfig {
    fn default() -> Self {
        MvuConfig {
            act_depth: 32 * 1024,   // 64-bit words
            weight_depth: 2048,     // 4096-bit words
            scaler_depth: 512,
            bias_depth: 512,
        }
    }
}

/// Execution state, as exposed through the status CSR.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MvuState {
    Idle,
    Running,
}

/// One 64-bit output word travelling through the crossbar to other MVU(s).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct XbarWrite {
    /// Destination MVU bitmask (bit i = MVU i; several bits = broadcast).
    pub dest_mask: u8,
    /// Destination activation-RAM word address.
    pub addr: u32,
    /// The bit-plane word.
    pub word: u64,
}

struct ActiveJob {
    cfg: JobConfig,
    /// MVP-side walk (combo sequencer + operand AGUs), shared with the
    /// turbo backend — see [`crate::mvu::JobWalk`].
    walk: JobWalk,
    /// Post-MVP pipeline (scaler → bias → pool/ReLU → QuantSer), likewise
    /// shared — see [`crate::mvu::OutputStage`].
    out: OutputStage,
    acc: [i64; BLOCK],
    outputs_done: u32,
}

/// One Matrix-Vector Unit.
pub struct Mvu {
    pub id: u8,
    pub act: ActRam,
    pub weights: WeightRam,
    pub scalers: ScalerRam,
    pub biases: BiasRam,
    job: Option<Box<ActiveJob>>,
    irq_pending: bool,
    /// Perf counter: MVP busy cycles since reset (CSR-visible).
    busy_cycles: u64,
    /// Perf counter: completed jobs since reset.
    jobs_done: u64,
}

impl Mvu {
    pub fn new(id: u8, cfg: MvuConfig) -> Self {
        Mvu {
            id,
            act: ActRam::new(cfg.act_depth),
            weights: WeightRam::new(cfg.weight_depth),
            scalers: ScalerRam::new(cfg.scaler_depth),
            biases: BiasRam::new(cfg.bias_depth),
            job: None,
            irq_pending: false,
            busy_cycles: 0,
            jobs_done: 0,
        }
    }

    pub fn state(&self) -> MvuState {
        if self.job.is_some() {
            MvuState::Running
        } else {
            MvuState::Idle
        }
    }

    pub fn irq_pending(&self) -> bool {
        self.irq_pending
    }

    pub fn clear_irq(&mut self) {
        self.irq_pending = false;
    }

    pub fn busy_cycles(&self) -> u64 {
        self.busy_cycles
    }

    pub fn jobs_done(&self) -> u64 {
        self.jobs_done
    }

    /// Reset all *run-scoped* state — activation RAM, the active job, the
    /// IRQ line and the perf counters — while keeping the weight, scaler and
    /// bias RAMs intact. This is the warm path of an inference session:
    /// weights persist across images, activations do not.
    pub fn reset_run_state(&mut self) {
        let depth = self.act.depth();
        self.act.clear(0, depth);
        self.job = None;
        self.irq_pending = false;
        self.busy_cycles = 0;
        self.jobs_done = 0;
    }

    /// Launch a job. Fails — typed, never a panic — when the MVU is still
    /// running (the controller must respect the status CSR) or when the
    /// configuration is inconsistent. Malformed CSR-programmed jobs are
    /// reachable from serving traffic, so the error is surfaced up the
    /// stack (`SystemExit::Fault` on the CSR path,
    /// `SessionError::Launch` through the session) instead of aborting the
    /// process and killing a coordinator worker thread.
    pub fn launch(&mut self, cfg: JobConfig) -> Result<(), String> {
        if self.job.is_some() {
            return Err(format!("MVU{} launch while busy", self.id));
        }
        cfg.validate()
            .map_err(|e| format!("MVU{} bad job config: {e}", self.id))?;
        let job = ActiveJob {
            walk: JobWalk::new(&cfg),
            out: OutputStage::new(&cfg),
            acc: [0; BLOCK],
            outputs_done: 0,
            cfg,
        };
        self.job = Some(Box::new(job));
        Ok(())
    }

    /// Remove a just-launched job and hand back its configuration — the
    /// turbo dispatch path in [`crate::accel::System`] converts a CSR
    /// `START` into a functional whole-job execution. Callers must invoke
    /// this before the job has consumed any cycles: re-running a
    /// partially-stepped job from scratch would double-count work and,
    /// for self-RAM jobs, read back its own partial outputs.
    pub(crate) fn take_launched_job(&mut self) -> Option<JobConfig> {
        let job = self.job.take()?;
        debug_assert_eq!(
            job.walk.steps_taken(),
            0,
            "MVU{}: turbo takeover of a job that already consumed cycles",
            self.id
        );
        Some(job.cfg)
    }

    /// Book a whole job's worth of completion state at once (turbo backend):
    /// the cycles the job would have occupied the MVP, the done counter and
    /// the completion IRQ.
    pub(crate) fn finish_job_accounting(&mut self, cycles: u64) {
        debug_assert!(self.job.is_none(), "MVU{} turbo accounting while busy", self.id);
        self.busy_cycles += cycles;
        self.jobs_done += 1;
        self.irq_pending = true;
    }

    /// Advance one clock cycle. Returns crossbar writes emitted this cycle
    /// (empty when idle, writing to self, or mid-accumulation).
    pub fn step(&mut self) -> Vec<XbarWrite> {
        let Some(job) = self.job.as_deref_mut() else {
            return Vec::new();
        };
        self.busy_cycles += 1;

        // --- MVP cycle -----------------------------------------------------
        let mac = job.walk.step();
        let act_word = self.act.read(mac.a_addr);
        let weight_word = self.weights.read(mac.w_addr);
        mac.apply(&mut job.acc, act_word, weight_word);
        if !mac.output_done {
            return Vec::new();
        }

        // --- output vector complete: post-MVP pipeline ----------------------
        let mvp_out: [i32; BLOCK] = std::array::from_fn(|l| job.acc[l] as i32);
        job.acc = [0; BLOCK];
        job.outputs_done += 1;

        let mut writes = Vec::new();
        job.out.push_to(
            &mvp_out,
            job.cfg.dest,
            &mut self.act,
            &self.scalers,
            &self.biases,
            &mut writes,
        );

        // --- job completion -------------------------------------------------
        if job.outputs_done == job.cfg.outputs {
            self.job = None;
            self.irq_pending = true;
            self.jobs_done += 1;
        }
        writes
    }

    /// Test/driver convenience: run the current job to completion, returning
    /// all crossbar writes and the number of cycles consumed.
    pub fn run_to_completion(&mut self) -> (Vec<XbarWrite>, u64) {
        let mut writes = Vec::new();
        let mut cycles = 0;
        while self.state() == MvuState::Running {
            writes.extend(self.step());
            cycles += 1;
        }
        (writes, cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mvu::agu::AguCfg;
    use crate::mvu::OutputDest;
    use crate::quant::{pack_block, Precision, QuantSerCfg};

    /// Build a weight-RAM image for a single 64×64 tile from a row-major
    /// matrix, at `prec` precision: word k' (MSB first) holds bit k of all
    /// rows.
    fn tile_words(m: &[[i32; 64]; 64], prec: Precision) -> Vec<[u64; 64]> {
        // Pack each row into its planes, then transpose plane-major.
        let rows: Vec<Vec<u64>> = m.iter().map(|r| pack_block(r, prec)).collect();
        (0..prec.bits as usize)
            .map(|p| std::array::from_fn(|r| rows[r][p]))
            .collect()
    }

    fn raw_quant() -> QuantSerCfg {
        // Identity-ish window wide enough to read back small accumulators.
        QuantSerCfg { msb_index: 15, out_bits: 16, saturate: false }
    }

    /// One 64×64 GEMV tile end-to-end through the MVU, vs golden gemv.
    #[test]
    fn single_tile_gemv_matches_golden() {
        let ap = Precision::u(2);
        let wp = Precision::s(2);
        let x: [i32; 64] = std::array::from_fn(|i| (i as i32 * 7 + 1) % 4);
        let w: [[i32; 64]; 64] =
            std::array::from_fn(|r| std::array::from_fn(|c| ((r * 64 + c) as i32 * 5 % 4) - 2));

        let mut mvu = Mvu::new(0, MvuConfig::default());
        mvu.act.load(0, &pack_block(&x, ap));
        mvu.weights.load(0, &tile_words(&w, wp));

        let job = JobConfig {
            aprec: ap,
            wprec: wp,
            tiles: 1,
            outputs: 1,
            a_agu: AguCfg::from_strides(0, &[]),
            w_agu: AguCfg::from_strides(0, &[]),
            s_agu: AguCfg::default(),
            b_agu: AguCfg::default(),
            o_agu: AguCfg::from_strides(1000, &[]),
            scaler_en: false,
            bias_en: false,
            relu_en: false,
            pool_count: 1,
            quant: raw_quant(),
            dest: OutputDest::SelfRam,
        };
        let expected_cycles = job.cycles();
        mvu.launch(job).unwrap();
        let (_, cycles) = mvu.run_to_completion();
        assert_eq!(cycles, expected_cycles);
        assert_eq!(cycles, 4, "2b×2b single tile = 4 cycles (§3.1.1)");
        assert!(mvu.irq_pending());

        // Read back the 16-bit output planes and compare with golden GEMV.
        let words: Vec<u64> = (0..16).map(|p| mvu.act.read(1000 + p)).collect();
        let got = crate::quant::unpack_block(&words, Precision::u(16));
        let wflat: Vec<i32> = w.iter().flatten().copied().collect();
        let want = crate::sim::gemv_i32(&wflat, &x, 64, 64);
        for l in 0..64 {
            assert_eq!(got[l], want[l] & 0xFFFF, "lane {l}");
        }
    }

    /// Multi-tile accumulation: a 128-element dot product (2 tiles).
    #[test]
    fn two_tile_accumulation() {
        let ap = Precision::u(3);
        let wp = Precision::s(3);
        let x0: [i32; 64] = std::array::from_fn(|i| (i as i32) % 8);
        let x1: [i32; 64] = std::array::from_fn(|i| (i as i32 * 3 + 1) % 8);
        let w0: [[i32; 64]; 64] =
            std::array::from_fn(|r| std::array::from_fn(|c| ((r + 2 * c) as i32 % 7) - 3));
        let w1: [[i32; 64]; 64] =
            std::array::from_fn(|r| std::array::from_fn(|c| ((3 * r + c) as i32 % 7) - 3));

        let mut mvu = Mvu::new(1, MvuConfig::default());
        // Tile bases: act blocks at 0 and 3 (3 planes each); weights at 0, 3.
        mvu.act.load(0, &pack_block(&x0, ap));
        mvu.act.load(3, &pack_block(&x1, ap));
        mvu.weights.load(0, &tile_words(&w0, wp));
        mvu.weights.load(3, &tile_words(&w1, wp));

        let job = JobConfig {
            aprec: ap,
            wprec: wp,
            tiles: 2,
            outputs: 1,
            // tile loop: 2 tiles, stride 3 (= prec.bits words per block);
            // replay loop: combos-1 = 8, stride 0.
            a_agu: AguCfg::from_strides(0, &[(1, 3), (8, 0)]),
            w_agu: AguCfg::from_strides(0, &[(1, 3), (8, 0)]),
            s_agu: AguCfg::default(),
            b_agu: AguCfg::default(),
            o_agu: AguCfg::from_strides(2000, &[]),
            scaler_en: false,
            bias_en: false,
            relu_en: false,
            pool_count: 1,
            quant: raw_quant(),
            dest: OutputDest::SelfRam,
        };
        mvu.launch(job).unwrap();
        let (_, cycles) = mvu.run_to_completion();
        assert_eq!(cycles, 9 * 2, "3b×3b × 2 tiles");

        let words: Vec<u64> = (0..16).map(|p| mvu.act.read(2000 + p)).collect();
        let got = crate::quant::unpack_block(&words, Precision::u(16));
        for r in 0..64 {
            let want: i64 = (0..64)
                .map(|c| (w0[r][c] * x0[c] + w1[r][c] * x1[c]) as i64)
                .sum();
            assert_eq!(got[r] as i64, want & 0xFFFF, "row {r}");
        }
    }

    /// Scaler, bias, ReLU and a tight QuantSer window.
    #[test]
    fn full_pipeline_requant() {
        let ap = Precision::u(1);
        let wp = Precision::s(2);
        let x = [1i32; 64];
        let w: [[i32; 64]; 64] =
            std::array::from_fn(|r| std::array::from_fn(|_| (r as i32 % 4) - 2));
        // Row dot products: r%4==0 → -128, 1 → -64, 2 → 0, 3 → 64.

        let mut mvu = Mvu::new(2, MvuConfig::default());
        mvu.act.load(0, &pack_block(&x, ap));
        mvu.weights.load(0, &tile_words(&w, wp));
        mvu.scalers.write(5, [2u16; 64]);
        mvu.biases.write(7, [64i32; 64]);

        let job = JobConfig {
            aprec: ap,
            wprec: wp,
            tiles: 1,
            outputs: 1,
            a_agu: AguCfg::from_strides(0, &[]),
            w_agu: AguCfg::from_strides(0, &[]),
            s_agu: AguCfg::from_strides(5, &[]),
            b_agu: AguCfg::from_strides(7, &[]),
            o_agu: AguCfg::from_strides(100, &[]),
            scaler_en: true,
            bias_en: true,
            relu_en: true,
            pool_count: 1,
            // v ∈ {-192, -64, 64, 192}; relu → {0,0,64,192};
            // select bits [7:6] → {0,0,1,3}.
            quant: QuantSerCfg { msb_index: 7, out_bits: 2, saturate: true },
            dest: OutputDest::SelfRam,
        };
        mvu.launch(job).unwrap();
        mvu.run_to_completion();

        let words: Vec<u64> = (0..2).map(|p| mvu.act.read(100 + p)).collect();
        let got = crate::quant::unpack_block(&words, Precision::u(2));
        for r in 0..64 {
            let want = match r % 4 {
                0 | 1 => 0,
                2 => 1,
                _ => 3,
            };
            assert_eq!(got[r], want, "row {r}");
        }
    }

    /// Xbar destination emits writes instead of touching local RAM.
    #[test]
    fn xbar_output() {
        let ap = Precision::u(1);
        let wp = Precision::u(1);
        let x = [1i32; 64];
        let w: [[i32; 64]; 64] = std::array::from_fn(|r| {
            std::array::from_fn(|c| if c <= r { 1 } else { 0 })
        });
        let mut mvu = Mvu::new(3, MvuConfig::default());
        mvu.act.load(0, &pack_block(&x, ap));
        mvu.weights.load(0, &tile_words(&w, wp));
        let job = JobConfig {
            aprec: ap,
            wprec: wp,
            tiles: 1,
            outputs: 1,
            a_agu: AguCfg::from_strides(0, &[]),
            w_agu: AguCfg::from_strides(0, &[]),
            s_agu: AguCfg::default(),
            b_agu: AguCfg::default(),
            o_agu: AguCfg::from_strides(40, &[]),
            scaler_en: false,
            bias_en: false,
            relu_en: false,
            pool_count: 1,
            quant: QuantSerCfg { msb_index: 7, out_bits: 8, saturate: false },
            dest: OutputDest::Xbar { dest_mask: 0b0001_0010 },
        };
        mvu.launch(job).unwrap();
        let (writes, _) = mvu.run_to_completion();
        assert_eq!(writes.len(), 8, "one write per output plane word");
        assert!(writes.iter().all(|w| w.dest_mask == 0b0001_0010));
        assert_eq!(writes[0].addr, 40);
        assert_eq!(writes[7].addr, 47);
        // Row r dot = r+1; plane words must decode back to that.
        let words: Vec<u64> = writes.iter().map(|w| w.word).collect();
        let got = crate::quant::unpack_block(&words, Precision::u(8));
        for r in 0..64 {
            assert_eq!(got[r], r as i32 + 1);
        }
    }

    /// Busy-cycle and job counters accumulate across jobs.
    #[test]
    fn perf_counters() {
        let ap = Precision::u(1);
        let wp = Precision::u(1);
        let mut mvu = Mvu::new(4, MvuConfig::default());
        mvu.act.load(0, &pack_block(&[1; 64], ap));
        mvu.weights.load(0, &tile_words(&[[1; 64]; 64], wp));
        let job = JobConfig {
            aprec: ap,
            wprec: wp,
            tiles: 1,
            outputs: 4,
            a_agu: AguCfg::from_strides(0, &[(3, 0)]),
            w_agu: AguCfg::from_strides(0, &[(3, 0)]),
            s_agu: AguCfg::default(),
            b_agu: AguCfg::default(),
            o_agu: AguCfg::from_strides(10, &[(3, 8)]),
            scaler_en: false,
            bias_en: false,
            relu_en: false,
            pool_count: 1,
            quant: QuantSerCfg { msb_index: 7, out_bits: 8, saturate: false },
            dest: OutputDest::SelfRam,
        };
        mvu.launch(job.clone()).unwrap();
        mvu.run_to_completion();
        mvu.clear_irq();
        mvu.launch(job).unwrap();
        mvu.run_to_completion();
        assert_eq!(mvu.busy_cycles(), 8);
        assert_eq!(mvu.jobs_done(), 2);
    }

    /// Max-pooling over 4 consecutive outputs writes one vector.
    #[test]
    fn pooled_outputs() {
        let ap = Precision::u(2);
        let wp = Precision::u(1);
        let mut mvu = Mvu::new(5, MvuConfig::default());
        // Four activation blocks with values 0,1,2,3 in every lane.
        for (i, v) in [0i32, 2, 3, 1].iter().enumerate() {
            mvu.act.load((i * 2) as u32, &pack_block(&[*v; 64], ap));
        }
        // Identity-ish weights: each row sums all 64 lanes → dot = 64*v.
        mvu.weights.load(0, &tile_words(&[[1; 64]; 64], wp));
        let job = JobConfig {
            aprec: ap,
            wprec: wp,
            tiles: 1,
            outputs: 4,
            // Output n reads act block n: tile loop trivial, combo replay 2,
            // output loop stride 2 planes.
            a_agu: AguCfg::from_strides(0, &[(0, 0), (1, 0), (3, 2)]),
            w_agu: AguCfg::from_strides(0, &[]),
            s_agu: AguCfg::default(),
            b_agu: AguCfg::default(),
            o_agu: AguCfg::from_strides(500, &[]),
            scaler_en: false,
            bias_en: false,
            relu_en: false,
            pool_count: 4,
            quant: QuantSerCfg { msb_index: 7, out_bits: 8, saturate: false },
            dest: OutputDest::SelfRam,
        };
        mvu.launch(job).unwrap();
        let (_, cycles) = mvu.run_to_completion();
        assert_eq!(cycles, 4 * 2 * 1);
        let words: Vec<u64> = (0..8).map(|p| mvu.act.read(500 + p)).collect();
        let got = crate::quant::unpack_block(&words, Precision::u(8));
        assert!(got.iter().all(|&v| v == 64 * 3), "max over {{0,128,192,64}}");
    }

    /// Regression: a malformed job config or a launch-while-busy is a typed
    /// error, not a process abort (reachable from CSR-launched serving
    /// traffic).
    #[test]
    fn bad_launches_error_instead_of_panicking() {
        let ap = Precision::u(2);
        let wp = Precision::s(2);
        let mut mvu = Mvu::new(6, MvuConfig::default());
        let good = JobConfig {
            aprec: ap,
            wprec: wp,
            tiles: 1,
            outputs: 1,
            a_agu: AguCfg::from_strides(0, &[]),
            w_agu: AguCfg::from_strides(0, &[]),
            s_agu: AguCfg::default(),
            b_agu: AguCfg::default(),
            o_agu: AguCfg::from_strides(100, &[]),
            scaler_en: false,
            bias_en: false,
            relu_en: false,
            pool_count: 1,
            quant: raw_quant(),
            dest: OutputDest::SelfRam,
        };
        let mut bad = good.clone();
        bad.tiles = 0;
        let err = mvu.launch(bad).unwrap_err();
        assert!(err.contains("bad job config"), "{err}");
        assert_eq!(mvu.state(), MvuState::Idle, "rejected launch leaves MVU idle");

        mvu.launch(good.clone()).unwrap();
        let err = mvu.launch(good).unwrap_err();
        assert!(err.contains("while busy"), "{err}");
        // The original job is untouched and still completes.
        let (_, cycles) = mvu.run_to_completion();
        assert_eq!(cycles, 4);
    }
}
