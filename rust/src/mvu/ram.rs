//! MVU-local memories (§3.1.2): activation, weight, scaler and bias RAMs.
//!
//! * **Activation RAM** — 64-bit words, bit-transposed activation blocks.
//! * **Weight RAM** — 4096-bit words (modelled as `[u64; 64]`): bit `k` of a
//!   64×64 weight tile, one 64-bit row per VVP.
//! * **Scaler RAM** — 64 × 16-bit operands per word (one per lane).
//! * **Bias RAM** — 64 × 32-bit operands per word.
//!
//! All reads/writes are bounds-checked; generated programs must stay within
//! the configured depth exactly as on the FPGA.

/// Rows per weight word = VVP count.
pub const WEIGHT_WORD_LANES: usize = 64;

/// Activation RAM: depth × 64-bit words.
#[derive(Debug, Clone)]
pub struct ActRam {
    words: Vec<u64>,
}

impl ActRam {
    pub fn new(depth: usize) -> Self {
        ActRam { words: vec![0; depth] }
    }

    pub fn depth(&self) -> usize {
        self.words.len()
    }

    #[inline]
    pub fn read(&self, addr: u32) -> u64 {
        self.words[addr as usize]
    }

    #[inline]
    pub fn write(&mut self, addr: u32, word: u64) {
        self.words[addr as usize] = word;
    }

    /// Bulk host-side load (PCIe DMA model): copy `words` starting at `addr`.
    pub fn load(&mut self, addr: u32, words: &[u64]) {
        let a = addr as usize;
        self.words[a..a + words.len()].copy_from_slice(words);
    }

    /// Zero a region (used to materialise padding rows/columns).
    pub fn clear(&mut self, addr: u32, len: usize) {
        let a = addr as usize;
        self.words[a..a + len].fill(0);
    }
}

/// Weight RAM: depth × 4096-bit words.
#[derive(Debug, Clone)]
pub struct WeightRam {
    words: Vec<[u64; WEIGHT_WORD_LANES]>,
}

impl WeightRam {
    pub fn new(depth: usize) -> Self {
        WeightRam { words: vec![[0; WEIGHT_WORD_LANES]; depth] }
    }

    pub fn depth(&self) -> usize {
        self.words.len()
    }

    #[inline]
    pub fn read(&self, addr: u32) -> &[u64; WEIGHT_WORD_LANES] {
        &self.words[addr as usize]
    }

    pub fn write(&mut self, addr: u32, word: [u64; WEIGHT_WORD_LANES]) {
        self.words[addr as usize] = word;
    }

    /// Bulk host-side load of a pre-transposed weight image.
    pub fn load(&mut self, addr: u32, words: &[[u64; WEIGHT_WORD_LANES]]) {
        let a = addr as usize;
        self.words[a..a + words.len()].copy_from_slice(words);
    }
}

/// Scaler RAM: depth × (64 × u16).
#[derive(Debug, Clone)]
pub struct ScalerRam {
    words: Vec<[u16; 64]>,
}

impl ScalerRam {
    pub fn new(depth: usize) -> Self {
        ScalerRam { words: vec![[1; 64]; depth] } // neutral scale = 1
    }

    pub fn depth(&self) -> usize {
        self.words.len()
    }

    #[inline]
    pub fn read(&self, addr: u32) -> &[u16; 64] {
        &self.words[addr as usize]
    }

    pub fn write(&mut self, addr: u32, word: [u16; 64]) {
        self.words[addr as usize] = word;
    }
}

/// Bias RAM: depth × (64 × i32).
#[derive(Debug, Clone)]
pub struct BiasRam {
    words: Vec<[i32; 64]>,
}

impl BiasRam {
    pub fn new(depth: usize) -> Self {
        BiasRam { words: vec![[0; 64]; depth] }
    }

    pub fn depth(&self) -> usize {
        self.words.len()
    }

    #[inline]
    pub fn read(&self, addr: u32) -> &[i32; 64] {
        &self.words[addr as usize]
    }

    pub fn write(&mut self, addr: u32, word: [i32; 64]) {
        self.words[addr as usize] = word;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn act_ram_rw() {
        let mut r = ActRam::new(16);
        r.write(3, 0xDEAD_BEEF);
        assert_eq!(r.read(3), 0xDEAD_BEEF);
        r.load(8, &[1, 2, 3]);
        assert_eq!(r.read(9), 2);
        r.clear(8, 3);
        assert_eq!(r.read(9), 0);
    }

    #[test]
    #[should_panic]
    fn act_ram_oob() {
        ActRam::new(4).read(4);
    }

    #[test]
    fn weight_ram_rw() {
        let mut r = WeightRam::new(4);
        let mut w = [0u64; 64];
        w[7] = 42;
        r.write(2, w);
        assert_eq!(r.read(2)[7], 42);
    }

    #[test]
    fn scaler_defaults_neutral() {
        let r = ScalerRam::new(2);
        assert_eq!(r.read(0)[13], 1);
    }
}
