//! Address-generation units (§3.1.3).
//!
//! "Each MVU contains address generation units (AGU) that drive the memory
//! access pattern across the activation and weight RAMs. The access pattern
//! is managed by a set of up to five nested loops with parameters setting
//! the number of iterations and the forward or backward address jumps to
//! make on each iteration."
//!
//! Semantics: the AGU holds a current address (initially `base`) and five
//! loop counters. On every `next()` it *emits* the current address, then
//! advances: the innermost loop whose counter has not reached its `count`
//! increments and its (signed) `jump` is added to the address; all loops
//! inside it reset. The AGU therefore emits `Π (count_i + 1)` addresses per
//! pass and then wraps around (restarting from `base`), so a single
//! configuration can be replayed across output vectors.

/// Number of nested loops in the hardware AGU.
pub const AGU_LOOPS: usize = 5;

/// One AGU loop: `count` extra iterations (total `count+1` passes of the
/// loop body) and the signed address `jump` applied each time this loop
/// advances.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AguLoop {
    pub count: u32,
    pub jump: i32,
}

/// Full AGU configuration: base address + five loops, `loops[0]` innermost.
/// Unused loops are left at `count: 0, jump: 0`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AguCfg {
    pub base: u32,
    pub loops: [AguLoop; AGU_LOOPS],
}

impl AguCfg {
    /// Build a configuration from *logical strides*: the caller specifies,
    /// per loop level (innermost first), how many extra iterations `count`
    /// and the desired address delta `stride` between successive iterations
    /// of that level. This converts strides into the hardware's relative
    /// jumps, which must rewind the accumulated delta of one complete pass
    /// of all inner loops `P_{i-1}`:
    ///
    /// `jump_i = stride_i − P_{i-1}` where
    /// `P_i = (count_i + 1) · P_{i-1} + count_i · jump_i`, `P_{-1} = 0`.
    pub fn from_strides(base: u32, levels: &[(u32, i64)]) -> AguCfg {
        assert!(levels.len() <= AGU_LOOPS, "AGU has only {AGU_LOOPS} loops");
        let mut loops = [AguLoop::default(); AGU_LOOPS];
        let mut inner_pass: i64 = 0; // P_{i-1}
        for (i, &(count, stride)) in levels.iter().enumerate() {
            let jump = stride - inner_pass;
            loops[i] = AguLoop {
                count,
                jump: i32::try_from(jump).expect("AGU jump overflows i32"),
            };
            inner_pass = (count as i64 + 1) * inner_pass + count as i64 * jump;
        }
        AguCfg { base, loops }
    }

    /// Total number of addresses emitted in one full pass.
    pub fn pass_len(&self) -> u64 {
        self.loops.iter().map(|l| l.count as u64 + 1).product()
    }

    /// Convenience: enumerate one full pass of addresses (test/debug aid;
    /// the hot path uses the incremental [`Agu`]).
    pub fn addresses(&self) -> Vec<u32> {
        let mut agu = Agu::new(*self);
        (0..self.pass_len()).map(|_| agu.next_addr()).collect()
    }
}

/// Live AGU state.
#[derive(Debug, Clone)]
pub struct Agu {
    cfg: AguCfg,
    addr: i64,
    counters: [u32; AGU_LOOPS],
}

impl Agu {
    pub fn new(cfg: AguCfg) -> Self {
        Agu { cfg, addr: cfg.base as i64, counters: [0; AGU_LOOPS] }
    }

    /// Emit the current address and advance to the next.
    #[inline]
    pub fn next_addr(&mut self) -> u32 {
        let emit = self.addr;
        debug_assert!(emit >= 0, "AGU address went negative: {emit}");
        // Advance: innermost non-exhausted loop jumps; inner ones reset.
        let mut advanced = false;
        for i in 0..AGU_LOOPS {
            if self.counters[i] < self.cfg.loops[i].count {
                self.counters[i] += 1;
                self.addr += self.cfg.loops[i].jump as i64;
                for c in self.counters[..i].iter_mut() {
                    *c = 0;
                }
                advanced = true;
                break;
            }
        }
        if !advanced {
            // Full pass complete: wrap to base for replay.
            self.counters = [0; AGU_LOOPS];
            self.addr = self.cfg.base as i64;
        }
        u32::try_from(emit).expect("AGU emitted negative address")
    }

    pub fn reset(&mut self) {
        self.addr = self.cfg.base as i64;
        self.counters = [0; AGU_LOOPS];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_loop_linear() {
        let cfg = AguCfg::from_strides(10, &[(4, 1)]);
        assert_eq!(cfg.addresses(), vec![10, 11, 12, 13, 14]);
    }

    #[test]
    fn two_level_with_gap() {
        // Inner: 3 addresses stride 1; outer: 2 rows stride 10.
        let cfg = AguCfg::from_strides(0, &[(2, 1), (1, 10)]);
        assert_eq!(cfg.addresses(), vec![0, 1, 2, 10, 11, 12]);
    }

    #[test]
    fn backward_jump_replay() {
        // Replay the same 3 addresses 4 times: outer stride 0 rewinds.
        let cfg = AguCfg::from_strides(7, &[(2, 1), (3, 0)]);
        let got = cfg.addresses();
        assert_eq!(got, vec![7, 8, 9, 7, 8, 9, 7, 8, 9, 7, 8, 9]);
        // The hardware jump for the replay loop must be negative.
        assert_eq!(cfg.loops[1].jump, -2);
    }

    #[test]
    fn three_level_conv_like() {
        // cb (2 blocks, stride 2 = aprec), fw (3 taps, stride 8), fh (3 rows,
        // stride 80): a miniature conv tile walk.
        let cfg = AguCfg::from_strides(100, &[(1, 2), (2, 8), (2, 80)]);
        let got = cfg.addresses();
        let mut want = Vec::new();
        for fh in 0..3i64 {
            for fw in 0..3i64 {
                for cb in 0..2i64 {
                    want.push((100 + cb * 2 + fw * 8 + fh * 80) as u32);
                }
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn wraps_after_full_pass() {
        let cfg = AguCfg::from_strides(5, &[(1, 1)]);
        let mut agu = Agu::new(cfg);
        assert_eq!(agu.next_addr(), 5);
        assert_eq!(agu.next_addr(), 6);
        // Wrapped.
        assert_eq!(agu.next_addr(), 5);
        assert_eq!(agu.next_addr(), 6);
    }

    #[test]
    fn pass_len() {
        let cfg = AguCfg::from_strides(0, &[(1, 1), (2, 3), (0, 0), (4, 9)]);
        assert_eq!(cfg.pass_len(), 2 * 3 * 1 * 5);
        assert_eq!(cfg.addresses().len(), 30);
    }

    #[test]
    fn five_levels() {
        let cfg = AguCfg::from_strides(0, &[(1, 1), (1, 2), (1, 4), (1, 8), (1, 16)]);
        let got = cfg.addresses();
        assert_eq!(got.len(), 32);
        // Address = bit pattern of counters: 0..=31 in order.
        assert_eq!(got, (0..32).collect::<Vec<u32>>());
    }
}
