//! Scaler + bias pipeline stage (§3.1.4): a 27×16 fixed-point multiplier
//! aligned to the FPGA DSP ports, followed by a 32-bit bias adder. Used for
//! batch-norm folding and LSQ quantization scaling.

use crate::quant::Fixed;

/// The 64-lane scaler/bias stage. Stateless per element; struct exists to
/// mirror the hardware module boundary and hold enables.
#[derive(Debug, Clone, Copy)]
pub struct ScalerStage {
    pub scaler_en: bool,
    pub bias_en: bool,
}

impl ScalerStage {
    /// Process one 64-lane vector: `v·s + b` per lane, at pipeline width.
    pub fn apply(&self, v: &[i32; 64], scales: &[u16; 64], biases: &[i32; 64]) -> [i32; 64] {
        std::array::from_fn(|l| {
            let mut f = Fixed(v[l]);
            if self.scaler_en {
                f = f.scale(scales[l]);
            }
            if self.bias_en {
                f = f.bias(biases[l]);
            }
            f.0
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_and_bias() {
        let st = ScalerStage { scaler_en: true, bias_en: true };
        let v = [2i32; 64];
        let s = [10u16; 64];
        let mut b = [0i32; 64];
        b[3] = 7;
        let out = st.apply(&v, &s, &b);
        assert_eq!(out[0], 20);
        assert_eq!(out[3], 27);
    }

    #[test]
    fn bypass() {
        let st = ScalerStage { scaler_en: false, bias_en: false };
        let v: [i32; 64] = std::array::from_fn(|i| i as i32 - 32);
        assert_eq!(st.apply(&v, &[9; 64], &[9; 64]), v);
    }

    #[test]
    fn negative_values_scale() {
        let st = ScalerStage { scaler_en: true, bias_en: false };
        let out = st.apply(&[-5; 64], &[3; 64], &[0; 64]);
        assert_eq!(out[0], -15);
    }
}
