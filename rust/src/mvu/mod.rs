//! The Matrix-Vector Unit (§3.1, Fig. 1 right, Fig. 4).
//!
//! Each MVU is a 64-lane vector pipeline:
//!
//! ```text
//!  act RAM ──64b──► ┌─────────────────────────┐
//!                   │ MVP: 64 × VVP            │ 64 × 32b
//!  wgt RAM ─4096b─► │ (bit-serial, Alg. 1)     ├─────────► Scaler ─► Bias
//!                   └─────────────────────────┘              (27×16)   (32b)
//!                                                               │
//!        act RAM (self or via crossbar) ◄── QuantSer ◄── Pool/ReLU
//! ```
//!
//! The MVP computes on 1–16-bit operands bit-serially: one activation word
//! (bit `j` of 64 elements) is broadcast to 64 VVPs while a 4096-bit weight
//! word (bit `k` of a 64×64 tile) feeds one row per VVP; each VVP ANDs,
//! popcounts through the adder tree and shift-accumulates by order of
//! magnitude. A `b_w × b_a`-bit job takes `b_w·b_a` cycles per accumulated
//! tile.
//!
//! Faithfulness note (documented in DESIGN.md): the address-generation units
//! produce *tile* addresses through five nested ± jump loops, while the
//! bit-plane offset within a tile (`prec-1-j`) is added by the MVP's bit
//! combination sequencer — the zigzag magnitude order of Alg. 1 is not
//! expressible as nested counters alone, and the real design likewise keeps
//! the bit-combination walk in dedicated sequencer logic.

mod agu;
mod job;
mod mvu;
mod pool;
mod ram;
mod scaler;
mod transposer;
mod vvp;
mod walk;

pub use agu::{Agu, AguCfg, AguLoop, AGU_LOOPS};
pub use job::{ComboSeq, JobConfig, OutputDest};
pub use mvu::{Mvu, MvuConfig, MvuState, XbarWrite};
pub use pool::PoolRelu;
pub use ram::{ActRam, BiasRam, ScalerRam, WeightRam, WEIGHT_WORD_LANES};
pub use scaler::ScalerStage;
pub use transposer::Transposer;
pub use vvp::Vvp;
pub use walk::{kernel_variant, popcount_block, JobWalk, MacStep, OutputStage};
