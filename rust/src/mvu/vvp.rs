//! The Vector-Vector Product pipeline (Fig. 4): 64 one-bit multipliers
//! (AND gates), a 5-deep adder tree producing an 8-bit partial dot product,
//! and a shifter-accumulator implementing the magnitude-ordered bit-serial
//! scheme of Algorithm 1.

/// One VVP lane-group: processes one 64-element row of the weight tile
/// against the broadcast activation word.
#[derive(Debug, Clone, Copy, Default)]
pub struct Vvp {
    /// Shifter-accumulator. 32-bit in hardware; modelled in i64 with a
    /// wrap-to-i32 on output so overflow is detectable in tests.
    acc: i64,
}

impl Vvp {
    pub fn new() -> Self {
        Vvp { acc: 0 }
    }

    /// Shift the accumulator left one bit — applied when the bit-combination
    /// sequencer moves to the next lower order of magnitude (Alg. 1 l.11).
    #[inline]
    pub fn shift(&mut self) {
        self.acc <<= 1;
    }

    /// One cycle: 64 1-bit products (AND), adder-tree sum (popcount) and
    /// signed accumulate. `sign` is −1 when exactly one of the current bit
    /// planes is a two's-complement sign plane.
    #[inline]
    pub fn mac(&mut self, act_word: u64, weight_row: u64, sign: i32) {
        let partial = (act_word & weight_row).count_ones() as i64;
        self.acc += sign as i64 * partial;
    }

    /// Read out and clear the accumulator at job-output boundaries.
    /// Truncates to the 32-bit pipeline width (wrapping, like hardware).
    #[inline]
    pub fn take(&mut self) -> i32 {
        let v = self.acc;
        self.acc = 0;
        v as i32
    }

    /// Current wide accumulator value (test/debug aid).
    pub fn value(&self) -> i64 {
        self.acc
    }
}

/// Compute a full bit-serial dot product over pre-packed bit planes —
/// a direct transcription of Algorithm 1, used as the unit-level oracle for
/// the streaming MVP and exercised by proptests.
///
/// `a_planes[j]` holds bit `j` (LSB = index 0) of the 64 activation
/// elements, `w_planes[k]` likewise for weights. Signs follow two's
/// complement when the corresponding precision is signed.
pub fn bitserial_dot(
    a_planes: &[u64],
    w_planes: &[u64],
    a_prec: crate::quant::Precision,
    w_prec: crate::quant::Precision,
) -> i32 {
    assert_eq!(a_planes.len(), a_prec.bits as usize);
    assert_eq!(w_planes.len(), w_prec.bits as usize);
    let mut vvp = Vvp::new();
    let top = (a_prec.bits - 1) as i32 + (w_prec.bits - 1) as i32;
    for i in (0..=top).rev() {
        if i != top {
            vvp.shift();
        }
        for j in 0..a_prec.bits as i32 {
            let k = i - j;
            if k < 0 || k >= w_prec.bits as i32 {
                continue;
            }
            let sign = a_prec.plane_sign(j as u8) * w_prec.plane_sign(k as u8);
            vvp.mac(a_planes[j as usize], w_planes[k as usize], sign);
        }
    }
    vvp.take()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{pack_block, Precision, BLOCK};

    /// Plain integer dot product oracle.
    fn dot(a: &[i32; BLOCK], w: &[i32; BLOCK]) -> i64 {
        a.iter().zip(w).map(|(&x, &y)| x as i64 * y as i64).sum()
    }

    /// Reorder packed planes from memory order (MSB first) to LSB-first as
    /// `bitserial_dot` expects.
    fn lsb_first(mem: Vec<u64>) -> Vec<u64> {
        mem.into_iter().rev().collect()
    }

    fn check(a: [i32; BLOCK], w: [i32; BLOCK], ap: Precision, wp: Precision) {
        let a_planes = lsb_first(pack_block(&a, ap));
        let w_planes = lsb_first(pack_block(&w, wp));
        let got = bitserial_dot(&a_planes, &w_planes, ap, wp) as i64;
        assert_eq!(got, dot(&a, &w), "ap={ap:?} wp={wp:?}");
    }

    #[test]
    fn unsigned_2x2() {
        let a: [i32; BLOCK] = std::array::from_fn(|i| (i as i32) % 4);
        let w: [i32; BLOCK] = std::array::from_fn(|i| (3 - i as i32 % 4) % 4);
        check(a, w, Precision::u(2), Precision::s(3));
    }

    #[test]
    fn unsigned_1x1_is_popcount() {
        let a = [1i32; BLOCK];
        let w: [i32; BLOCK] = std::array::from_fn(|i| (i % 2) as i32);
        check(a, w, Precision::u(1), Precision::u(1));
    }

    #[test]
    fn signed_weights() {
        let a: [i32; BLOCK] = std::array::from_fn(|i| (i as i32 * 3) % 4);
        let w: [i32; BLOCK] = std::array::from_fn(|i| ((i as i32 * 7) % 4) - 2);
        check(a, w, Precision::u(2), Precision::s(2));
    }

    #[test]
    fn signed_both() {
        let a: [i32; BLOCK] = std::array::from_fn(|i| ((i as i32 * 5) % 16) - 8);
        let w: [i32; BLOCK] = std::array::from_fn(|i| ((i as i32 * 11) % 16) - 8);
        check(a, w, Precision::s(4), Precision::s(4));
    }

    #[test]
    fn mixed_precision() {
        for (ab, wb) in [(1u8, 4u8), (3, 2), (8, 8), (5, 7), (16, 1)] {
            let ap = Precision::u(ab);
            let wp = Precision::s(wb);
            let a: [i32; BLOCK] =
                std::array::from_fn(|i| (i as i32 * 13 + 1) % (1 << ab));
            let span = (1 << wb) as i32;
            let w: [i32; BLOCK] =
                std::array::from_fn(|i| ((i as i32 * 17 + 3) % span) - span / 2);
            check(a, w, ap, wp);
        }
    }

    #[test]
    fn take_resets() {
        let mut v = Vvp::new();
        v.mac(0b1111, 0b0110, 1);
        assert_eq!(v.take(), 2);
        assert_eq!(v.take(), 0);
    }

    #[test]
    fn shift_doubles() {
        let mut v = Vvp::new();
        v.mac(1, 1, 1);
        v.shift();
        v.mac(1, 1, 1);
        assert_eq!(v.take(), 3);
    }
}
