//! The shared job walk: the exact traversal one MVU job makes over its
//! RAMs, factored out of the per-cycle stepper so that *every* execution
//! backend consumes the same address/sign/shift sequence.
//!
//! A job's numerics are fully determined by its RAM contents plus this
//! walk (§3.1.3): the bit-combination sequencer supplies the `(j, k)`
//! plane pair, the activation/weight AGUs supply the tile base addresses,
//! and the plane offset `bits−1−j` is added by the sequencer. The
//! cycle-accurate stepper ([`super::Mvu::step`]) advances the walk one MAC
//! per modelled clock; the turbo backend ([`crate::exec::run_job_turbo`])
//! drains it one output vector at a time. Both observe bit-identical
//! addresses in bit-identical order, which is what makes the backends
//! interchangeable.

use crate::quant::BLOCK;

use super::agu::Agu;
use super::job::{ComboSeq, JobConfig, OutputDest};
use super::mvu::XbarWrite;
use super::pool::PoolRelu;
use super::ram::{ActRam, BiasRam, ScalerRam};
use super::scaler::ScalerStage;

/// One MVP cycle of the walk: which words to read, how to combine them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MacStep {
    /// Activation-RAM word address (tile base + plane offset).
    pub a_addr: u32,
    /// Weight-RAM word address (tile base + plane offset).
    pub w_addr: u32,
    /// ±1 contribution sign of this bit-plane pair (−1 when exactly one
    /// plane is a two's-complement sign plane).
    pub sign: i32,
    /// Shift the accumulator left one bit *before* this MAC (the sequencer
    /// moved down one order of magnitude, Alg. 1 l.11).
    pub shift: bool,
    /// This MAC completes an output vector: read out the accumulator and
    /// run the post-MVP pipeline.
    pub output_done: bool,
}

impl MacStep {
    /// Apply this MAC to the 64-lane accumulator: the one numeric kernel
    /// both backends execute (shift, then 64 × AND + POPCNT ± accumulate).
    /// Living here — not duplicated per backend — is what keeps the
    /// bit-for-bit backend-equivalence contract a structural property.
    ///
    /// §Perf: branch on the plane sign outside the lane loop so the body
    /// is a pure AND+POPCNT+ADD chain the compiler can vectorize. The
    /// turbo trace replay ([`crate::exec::JobTrace`]) hoists the sign and
    /// shift even further — once per *run* of uniform MACs — and funnels
    /// the popcounts through [`popcount_block`]; both paths compute the
    /// exact same integer sums.
    #[inline]
    pub fn apply(&self, acc: &mut [i64; BLOCK], act_word: u64, weight_word: &[u64; BLOCK]) {
        if self.shift {
            for a in acc.iter_mut() {
                *a <<= 1;
            }
        }
        if self.sign >= 0 {
            for (lane, row) in weight_word.iter().enumerate() {
                acc[lane] += (act_word & row).count_ones() as i64;
            }
        } else {
            for (lane, row) in weight_word.iter().enumerate() {
                acc[lane] -= (act_word & row).count_ones() as i64;
            }
        }
    }
}

/// The word-parallel popcount kernel: accumulate
/// `popcnt(act_word & rows[lane])` into `run_acc[lane]` for all 64 lanes —
/// one activation word ANDed against a full 4096-bit weight word per call.
/// Sign and shift are *not* applied here; the turbo trace replay resolves
/// them once per run of uniform MACs, which is what makes this body a
/// branch-free unsigned ADD chain the compiler can vectorize.
///
/// Dispatches once per call (the CPU-feature probe is cached by `std`) to
/// an explicit wide variant where the host allows, falling back to the
/// blocked portable loop. Both variants compute identical integer sums —
/// popcount has one right answer — so kernel choice can never perturb the
/// bit-for-bit backend-equivalence contract.
#[inline]
pub fn popcount_block(run_acc: &mut [u64; BLOCK], act_word: u64, rows: &[u64; BLOCK]) {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("popcnt")
        {
            // SAFETY: both features were just probed at runtime.
            unsafe { popcount_block_x86(run_acc, act_word, rows) };
            return;
        }
    }
    popcount_block_portable(run_acc, act_word, rows)
}

/// Which [`popcount_block`] variant this host resolves to (reported in
/// `BENCH_hotpath.json` so perf snapshots record the kernel they measured).
pub fn kernel_variant() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("popcnt")
        {
            return "x86_64-avx2-popcnt";
        }
    }
    "portable-blocked"
}

/// Portable kernel: 8-lane blocks via `chunks_exact` so the backend sees a
/// fixed-trip-count inner loop it can unroll and autovectorize (`BLOCK` is
/// 64, so the remainder is empty by construction).
#[inline]
fn popcount_block_portable(run_acc: &mut [u64; BLOCK], act_word: u64, rows: &[u64; BLOCK]) {
    for (accs, rws) in run_acc.chunks_exact_mut(8).zip(rows.chunks_exact(8)) {
        for (a, r) in accs.iter_mut().zip(rws) {
            *a += (act_word & r).count_ones() as u64;
        }
    }
}

/// The explicit `std::arch`-gated variant: the same portable body compiled
/// with AVX2 + POPCNT enabled, so LLVM lowers `count_ones` to hardware
/// `popcnt` / vectorized byte-shuffle popcounts instead of the baseline
/// SWAR sequence. Numerically identical to the portable kernel.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,popcnt")]
unsafe fn popcount_block_x86(run_acc: &mut [u64; BLOCK], act_word: u64, rows: &[u64; BLOCK]) {
    popcount_block_portable(run_acc, act_word, rows)
}

/// MVP-side walk state for one job: the combo sequencer, the two operand
/// AGUs and the tile counter.
#[derive(Debug, Clone)]
pub struct JobWalk {
    combos: ComboSeq,
    a_agu: Agu,
    w_agu: Agu,
    a_bits: u8,
    w_bits: u8,
    tiles: u32,
    combo_idx: usize,
    tile_idx: u32,
    steps_taken: u64,
}

impl JobWalk {
    pub fn new(cfg: &JobConfig) -> Self {
        JobWalk {
            combos: ComboSeq::new(cfg.aprec, cfg.wprec),
            a_agu: Agu::new(cfg.a_agu),
            w_agu: Agu::new(cfg.w_agu),
            a_bits: cfg.aprec.bits,
            w_bits: cfg.wprec.bits,
            tiles: cfg.tiles,
            combo_idx: 0,
            tile_idx: 0,
            steps_taken: 0,
        }
    }

    /// MVP cycles consumed per output vector (`b_a · b_w · tiles`).
    pub fn cycles_per_output(&self) -> u64 {
        self.combos.len() as u64 * self.tiles as u64
    }

    /// Total MACs emitted so far (= MVP cycles this job has consumed).
    pub fn steps_taken(&self) -> u64 {
        self.steps_taken
    }

    /// Advance one MVP cycle: emit the addresses/sign/shift for this MAC
    /// and move the sequencer forward.
    #[inline]
    pub fn step(&mut self) -> MacStep {
        let (j, k, shift, sign) = self.combos.steps[self.combo_idx];
        // Shift only on the first tile of a shifting combo step.
        let shift = shift && self.tile_idx == 0;
        // AGUs emit tile-base addresses; the sequencer adds the bit-plane
        // offset (planes are stored MSB-first within each block).
        let a_addr = self.a_agu.next_addr() + (self.a_bits - 1 - j) as u32;
        let w_addr = self.w_agu.next_addr() + (self.w_bits - 1 - k) as u32;
        self.steps_taken += 1;
        self.tile_idx += 1;
        let mut output_done = false;
        if self.tile_idx == self.tiles {
            self.tile_idx = 0;
            self.combo_idx += 1;
            if self.combo_idx == self.combos.len() {
                self.combo_idx = 0;
                output_done = true;
            }
        }
        MacStep { a_addr, w_addr, sign, shift, output_done }
    }
}

/// The post-MVP output pipeline shared by both backends: scaler → bias →
/// pool/ReLU → QuantSer, applied once per completed MVP output vector.
#[derive(Debug, Clone)]
pub struct OutputStage {
    s_agu: Agu,
    b_agu: Agu,
    o_agu: Agu,
    scaler: ScalerStage,
    pool: PoolRelu,
    quant: crate::quant::QuantSerCfg,
}

impl OutputStage {
    pub fn new(cfg: &JobConfig) -> Self {
        OutputStage {
            s_agu: Agu::new(cfg.s_agu),
            b_agu: Agu::new(cfg.b_agu),
            o_agu: Agu::new(cfg.o_agu),
            scaler: ScalerStage { scaler_en: cfg.scaler_en, bias_en: cfg.bias_en },
            pool: PoolRelu::new(cfg.relu_en, cfg.pool_count),
            quant: cfg.quant,
        }
    }

    /// Feed one completed MVP output vector through the pipeline. When the
    /// pool window fills, returns the output base address plus the
    /// requantized plane words: plane `p` (MSB plane first) is the word
    /// destined for address `base + p`, for `p < quant.out_bits`.
    pub fn push(
        &mut self,
        mvp_out: &[i32; BLOCK],
        scalers: &ScalerRam,
        biases: &BiasRam,
    ) -> Option<(u32, [u64; 16])> {
        let s_word = *scalers.read(self.s_agu.next_addr());
        let b_word = *biases.read(self.b_agu.next_addr());
        let scaled = self.scaler.apply(mvp_out, &s_word, &b_word);
        let pooled = self.pool.push(&scaled)?;
        // QuantSer: requantize each lane and serialize to `out_bits`
        // bit-plane words, MSB plane first.
        let q: [u32; BLOCK] =
            std::array::from_fn(|l| crate::quant::quantser(pooled[l], self.quant));
        let base = self.o_agu.next_addr();
        let ob = self.quant.out_bits as usize;
        let mut planes = [0u64; 16];
        for (p, word) in planes.iter_mut().enumerate().take(ob) {
            let bit = ob - 1 - p; // plane p stores bit (ob-1-p)
            for (l, &qv) in q.iter().enumerate() {
                if (qv >> bit) & 1 == 1 {
                    *word |= 1 << l;
                }
            }
        }
        Some((base, planes))
    }

    /// Feed one completed MVP output vector all the way out: run the
    /// pipeline ([`Self::push`]) and, when the pool window fills, commit
    /// the plane words to their destination — the MVU's own activation RAM
    /// or the crossbar write stream. The one dest-dispatch loop both
    /// backends execute; living here keeps addressing and plane order a
    /// shared, structural property.
    pub fn push_to(
        &mut self,
        mvp_out: &[i32; BLOCK],
        dest: OutputDest,
        act: &mut ActRam,
        scalers: &ScalerRam,
        biases: &BiasRam,
        writes: &mut Vec<XbarWrite>,
    ) {
        let Some((base, planes)) = self.push(mvp_out, scalers, biases) else {
            return;
        };
        for p in 0..self.quant.out_bits as u32 {
            let word = planes[p as usize];
            match dest {
                OutputDest::SelfRam => act.write(base + p, word),
                OutputDest::Xbar { dest_mask } => {
                    writes.push(XbarWrite { dest_mask, addr: base + p, word })
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mvu::agu::AguCfg;
    use crate::mvu::job::OutputDest;
    use crate::quant::{Precision, QuantSerCfg};

    fn walk_job(ab: u8, wb: u8, tiles: u32, outputs: u32) -> JobConfig {
        JobConfig {
            aprec: Precision::u(ab),
            wprec: Precision::s(wb),
            tiles,
            outputs,
            a_agu: AguCfg::from_strides(0, &[(tiles - 1, ab as i64)]),
            w_agu: AguCfg::from_strides(0, &[(tiles - 1, wb as i64)]),
            s_agu: AguCfg::default(),
            b_agu: AguCfg::default(),
            o_agu: AguCfg::from_strides(100, &[]),
            scaler_en: false,
            bias_en: false,
            relu_en: false,
            pool_count: 1,
            quant: QuantSerCfg { msb_index: 7, out_bits: 8, saturate: false },
            dest: OutputDest::SelfRam,
        }
    }

    /// The walk emits exactly `cycles()` MACs and flags output boundaries
    /// at `b_a·b_w·tiles` intervals.
    #[test]
    fn walk_length_and_output_boundaries() {
        let cfg = walk_job(3, 2, 4, 2);
        let mut walk = JobWalk::new(&cfg);
        assert_eq!(walk.cycles_per_output(), 3 * 2 * 4);
        let mut outputs = 0;
        for i in 0..cfg.cycles() {
            let s = walk.step();
            let boundary = (i + 1) % walk.cycles_per_output() == 0;
            assert_eq!(s.output_done, boundary, "MAC {i}");
            if s.output_done {
                outputs += 1;
            }
        }
        assert_eq!(outputs, cfg.outputs);
    }

    /// Shift flags fire once per magnitude-level change, on the first tile
    /// of the combo only.
    #[test]
    fn walk_shift_count_matches_combo_seq() {
        let cfg = walk_job(3, 3, 5, 1);
        let mut walk = JobWalk::new(&cfg);
        let shifts = (0..cfg.cycles()).filter(|_| walk.step().shift).count();
        // Levels − 1 per output replay.
        assert_eq!(shifts, (3 + 3 - 2) as usize);
    }

    /// Addresses follow AGU bases + MSB-first plane offsets.
    #[test]
    fn walk_addresses_add_plane_offsets() {
        let cfg = walk_job(2, 2, 1, 1);
        let mut walk = JobWalk::new(&cfg);
        // Combo order for 2×2 is (1,1),(1,0),(0,1),(0,0); offsets are
        // bits−1−j / bits−1−k from base 0.
        let want = [(0u32, 0u32), (0, 1), (1, 0), (1, 1)];
        for (i, &(a, w)) in want.iter().enumerate() {
            let s = walk.step();
            assert_eq!((s.a_addr, s.w_addr), (a, w), "MAC {i}");
        }
    }

    /// OutputStage matches a hand-rolled scaler→bias→quantser on one vector.
    #[test]
    fn output_stage_requantizes() {
        let mut cfg = walk_job(1, 1, 1, 1);
        cfg.scaler_en = true;
        cfg.bias_en = true;
        cfg.s_agu = AguCfg::from_strides(3, &[]);
        cfg.b_agu = AguCfg::from_strides(4, &[]);
        cfg.quant = QuantSerCfg { msb_index: 7, out_bits: 4, saturate: true };
        let mut scalers = ScalerRam::new(8);
        let mut biases = BiasRam::new(8);
        scalers.write(3, [2u16; 64]);
        biases.write(4, [5i32; 64]);
        let mut stage = OutputStage::new(&cfg);
        let mvp_out: [i32; BLOCK] = std::array::from_fn(|l| l as i32);
        let (base, planes) = stage.push(&mvp_out, &scalers, &biases).unwrap();
        assert_eq!(base, 100);
        let words: Vec<u64> = planes[..4].to_vec();
        let got = crate::quant::unpack_block(&words, Precision::u(4));
        for (l, &g) in got.iter().enumerate() {
            let want = crate::quant::quantser(l as i32 * 2 + 5, cfg.quant) as i32;
            assert_eq!(g, want, "lane {l}");
        }
    }
}
