//! Job configuration and the bit-combination sequencer (§3.1.3).
//!
//! A *job* is one CSR-programmed unit of work: e.g. one output row of a
//! Conv2D layer or one GEMV pass. The controller writes the configuration
//! registers, pulses the start command and receives an interrupt when the
//! job completes.

use crate::quant::Precision;

use super::agu::AguCfg;

/// Where the QuantSer output words go (§3.1.5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutputDest {
    /// Write back to this MVU's own activation RAM.
    SelfRam,
    /// Send through the crossbar to the activation RAM(s) of the MVUs in
    /// `dest_mask` (bit i = MVU i; multiple bits = broadcast).
    Xbar { dest_mask: u8 },
}

/// Full job configuration — the software-visible contract of one MVU job.
/// The CSR file (accel::csr_map) decodes into exactly this struct.
#[derive(Debug, Clone, PartialEq)]
pub struct JobConfig {
    /// Activation operand precision.
    pub aprec: Precision,
    /// Weight operand precision.
    pub wprec: Precision,
    /// Number of (activation word × weight word) tiles accumulated into each
    /// output vector (e.g. `C_b · F_H · F_W` for a conv row job).
    pub tiles: u32,
    /// Number of MVP output vectors this job produces (e.g. `W_out`).
    pub outputs: u32,
    /// Activation tile-base AGU: must emit `tiles` addresses per bit
    /// combination, replayed `aprec.bits·wprec.bits` times per output
    /// (the MVP adds the bit-plane offset `aprec.bits-1-j`).
    pub a_agu: AguCfg,
    /// Weight tile-base AGU, mirroring `a_agu` (offset `wprec.bits-1-k`).
    pub w_agu: AguCfg,
    /// Scaler RAM AGU: one address per MVP output vector.
    pub s_agu: AguCfg,
    /// Bias RAM AGU: one address per MVP output vector.
    pub b_agu: AguCfg,
    /// Output AGU: one base address per *written* output vector
    /// (`outputs / pool_count` of them); QuantSer writes `oprec` consecutive
    /// plane words from each base.
    pub o_agu: AguCfg,
    /// Enable the scaler multiply stage.
    pub scaler_en: bool,
    /// Enable the bias add stage.
    pub bias_en: bool,
    /// Enable ReLU in the pool/ReLU comparator.
    pub relu_en: bool,
    /// Max-pool window: the pool unit reduces every `pool_count` consecutive
    /// MVP outputs into one written output (1 = pooling off).
    pub pool_count: u32,
    /// Output precision / QuantSer window.
    pub quant: crate::quant::QuantSerCfg,
    /// Output destination.
    pub dest: OutputDest,
}

impl JobConfig {
    /// Bit combinations per output = `b_a · b_w` (§3.1.1).
    pub fn bit_combos(&self) -> u32 {
        self.aprec.bits as u32 * self.wprec.bits as u32
    }

    /// Total MVP cycles for the job: `outputs · b_a · b_w · tiles`.
    pub fn cycles(&self) -> u64 {
        self.outputs as u64 * self.bit_combos() as u64 * self.tiles as u64
    }

    /// Number of output vectors actually written after pooling.
    pub fn written_outputs(&self) -> u32 {
        self.outputs / self.pool_count
    }

    /// Validate internal consistency; returns a description of the first
    /// problem found.
    pub fn validate(&self) -> Result<(), String> {
        if self.tiles == 0 || self.outputs == 0 {
            return Err("tiles and outputs must be non-zero".into());
        }
        if self.pool_count == 0 || self.outputs % self.pool_count != 0 {
            return Err(format!(
                "pool_count {} must divide outputs {}",
                self.pool_count, self.outputs
            ));
        }
        if self.quant.out_bits < 1 || self.quant.out_bits > 16 {
            return Err("quant.out_bits must be 1..=16".into());
        }
        // The quantser window shift() asserts internally; check here softly.
        if self.quant.msb_index + 1 < self.quant.out_bits {
            return Err("quantser window underflows bit 0".into());
        }
        if let super::job::OutputDest::Xbar { dest_mask } = self.dest {
            if dest_mask == 0 {
                return Err("xbar destination mask is empty".into());
            }
        }
        Ok(())
    }
}

/// The bit-combination sequencer: walks all `(j, k)` activation/weight bit
/// pairs in descending order of magnitude `j + k` (Algorithm 1), flagging
/// the steps where the shifter-accumulator must shift.
///
/// The sequence is precomputed at job launch (it is at most 16×16 = 256
/// entries) and replayed once per output vector.
#[derive(Debug, Clone)]
pub struct ComboSeq {
    /// `(j, k, shift_before, sign)` per combination step.
    pub steps: Vec<(u8, u8, bool, i32)>,
}

impl ComboSeq {
    pub fn new(aprec: Precision, wprec: Precision) -> Self {
        let mut steps = Vec::with_capacity(aprec.bits as usize * wprec.bits as usize);
        let top = (aprec.bits - 1) as i32 + (wprec.bits - 1) as i32;
        let mut first_of_level;
        for i in (0..=top).rev() {
            first_of_level = true;
            for j in (0..aprec.bits as i32).rev() {
                let k = i - j;
                if k < 0 || k >= wprec.bits as i32 {
                    continue;
                }
                let sign = aprec.plane_sign(j as u8) * wprec.plane_sign(k as u8);
                // Shift once when entering a new magnitude level (except the
                // first level overall).
                let shift = first_of_level && i != top;
                steps.push((j as u8, k as u8, shift, sign));
                first_of_level = false;
            }
        }
        debug_assert_eq!(steps.len(), aprec.bits as usize * wprec.bits as usize);
        ComboSeq { steps }
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantSerCfg;

    fn dummy_job() -> JobConfig {
        JobConfig {
            aprec: Precision::u(2),
            wprec: Precision::s(2),
            tiles: 9,
            outputs: 32,
            a_agu: AguCfg::default(),
            w_agu: AguCfg::default(),
            s_agu: AguCfg::default(),
            b_agu: AguCfg::default(),
            o_agu: AguCfg::default(),
            scaler_en: true,
            bias_en: true,
            relu_en: true,
            pool_count: 1,
            quant: QuantSerCfg { msb_index: 7, out_bits: 2, saturate: true },
            dest: OutputDest::SelfRam,
        }
    }

    #[test]
    fn cycle_count_formula() {
        let j = dummy_job();
        // 32 outputs × (2·2) combos × 9 tiles.
        assert_eq!(j.cycles(), 32 * 4 * 9);
    }

    #[test]
    fn combo_seq_order_2x2() {
        let seq = ComboSeq::new(Precision::u(2), Precision::u(2));
        // Magnitudes: (1,1)=2 then (1,0),(0,1)=1 then (0,0)=0.
        let jk: Vec<(u8, u8)> = seq.steps.iter().map(|s| (s.0, s.1)).collect();
        assert_eq!(jk, vec![(1, 1), (1, 0), (0, 1), (0, 0)]);
        let shifts: Vec<bool> = seq.steps.iter().map(|s| s.2).collect();
        assert_eq!(shifts, vec![false, true, false, true]);
    }

    #[test]
    fn combo_seq_signs() {
        let seq = ComboSeq::new(Precision::u(2), Precision::s(2));
        // Sign plane of weights is k=1: steps with k==1 are negative.
        for &(_, k, _, sign) in &seq.steps {
            assert_eq!(sign, if k == 1 { -1 } else { 1 });
        }
    }

    #[test]
    fn magnitudes_non_increasing() {
        for (ab, wb) in [(3u8, 5u8), (8, 8), (1, 7), (16, 16)] {
            let seq = ComboSeq::new(Precision::u(ab), Precision::u(wb));
            let mags: Vec<i32> =
                seq.steps.iter().map(|s| s.0 as i32 + s.1 as i32).collect();
            assert!(mags.windows(2).all(|w| w[0] >= w[1]), "{ab}x{wb}: {mags:?}");
            assert_eq!(seq.len(), ab as usize * wb as usize);
            // Shift count = number of magnitude levels − 1.
            let shifts = seq.steps.iter().filter(|s| s.2).count();
            assert_eq!(shifts, (ab + wb - 2) as usize);
        }
    }

    #[test]
    fn validation() {
        let mut j = dummy_job();
        assert!(j.validate().is_ok());
        j.pool_count = 5; // does not divide 32
        assert!(j.validate().is_err());
        j.pool_count = 4;
        assert!(j.validate().is_ok());
        assert_eq!(j.written_outputs(), 8);
        j.dest = OutputDest::Xbar { dest_mask: 0 };
        assert!(j.validate().is_err());
    }
}
