//! The transposer module (§3.1.2): converts host-order element data into
//! the bit-transposed format on the way into activation RAM. "Transposition
//! is only needed on the first layer of a DNN since MVUs write back to
//! activation RAM in the bit-transposed format."
//!
//! Hardware streams elements in and flushes one bit-plane block (`prec.bits`
//! words) every 64 elements; we model exactly that streaming contract.

use crate::quant::{pack_block, Precision, BLOCK};

/// Streaming host→RAM transposer.
#[derive(Debug, Clone)]
pub struct Transposer {
    prec: Precision,
    buf: Vec<i32>,
}

impl Transposer {
    pub fn new(prec: Precision) -> Self {
        Transposer { prec, buf: Vec::with_capacity(BLOCK) }
    }

    /// Feed one element; returns a completed block of `prec.bits` plane
    /// words (MSB first) every 64th element.
    pub fn push(&mut self, v: i32) -> Option<Vec<u64>> {
        debug_assert!(self.prec.contains(v), "{v} not representable at {:?}", self.prec);
        self.buf.push(v);
        if self.buf.len() == BLOCK {
            let mut block = [0i32; BLOCK];
            block.copy_from_slice(&self.buf);
            self.buf.clear();
            Some(pack_block(&block, self.prec))
        } else {
            None
        }
    }

    /// Number of elements currently buffered (must be 0 at end of stream).
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Transpose a full element stream (length multiple of 64) into the
    /// concatenated plane-word image to DMA into activation RAM.
    pub fn transpose_all(prec: Precision, vals: &[i32]) -> Vec<u64> {
        assert!(vals.len() % BLOCK == 0, "stream must be a multiple of {BLOCK}");
        let mut t = Transposer::new(prec);
        let mut out = Vec::with_capacity(vals.len() / BLOCK * prec.bits as usize);
        for &v in vals {
            if let Some(words) = t.push(v) {
                out.extend_from_slice(&words);
            }
        }
        debug_assert_eq!(t.pending(), 0);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::BitTensor;

    #[test]
    fn streaming_matches_bulk_pack() {
        let prec = Precision::u(3);
        let vals: Vec<i32> = (0..2 * BLOCK as i32).map(|i| i % 8).collect();
        let streamed = Transposer::transpose_all(prec, &vals);
        let bulk = BitTensor::pack(&vals, prec);
        assert_eq!(streamed, bulk.words);
    }

    #[test]
    fn emits_every_64_elements() {
        let mut t = Transposer::new(Precision::u(2));
        for i in 0..63 {
            assert!(t.push(i % 4).is_none());
        }
        let words = t.push(3).expect("64th element flushes");
        assert_eq!(words.len(), 2);
        assert_eq!(t.pending(), 0);
    }

    #[test]
    fn signed_stream() {
        let prec = Precision::s(4);
        let vals: Vec<i32> = (0..BLOCK as i32).map(|i| (i % 15) - 7).collect();
        let words = Transposer::transpose_all(prec, &vals);
        let t = BitTensor { words, blocks: 1, prec };
        assert_eq!(t.unpack(), vals);
    }
}
