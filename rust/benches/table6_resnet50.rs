//! Table 6: ResNet-50/ImageNet — BARVINN vs FINN-R vs FILM-QNN.
//! Shape claims asserted: FINN holds the highest raw FPS, BARVINN the best
//! FPS/Watt, FILM-QNN far behind on both; and FINN's build needs most of
//! the U250 while BARVINN's footprint is model-independent (~15%).

use barvinn::model::zoo;
use barvinn::perf::benchkit::report_table;
use barvinn::perf::{cycle_model, film_qnn, finn, resource_model};
use barvinn::CLOCK_HZ;

fn main() {
    let net = zoo::resnet50_imagenet();
    let accel = cycle_model::accel_portion(&net);
    let bits = cycle_model::Bits { w: 1, a: 2 };

    let ours_fps = cycle_model::fps_pipelined_streamed(&accel, bits, CLOCK_HZ);
    let ours_power = resource_model::overall_resources().dynamic_power_w;
    let ours_fpw = ours_fps / ours_power;

    // FINN-R at its published throughput (2873 FPS @178 MHz, ~70 W class
    // U250 build per its 41.0 FPS/W).
    let finn_fps = 2873.0;
    let _finn_power = finn_fps / 41.0;
    let finn_luts = finn::luts_for_fps(&net, bits, finn_fps);

    let film = film_qnn::estimate_fps(&net, 13.0);

    let rows = vec![
        vec![
            "BARVINN (model)".into(),
            "1/2".into(),
            "250 MHz".into(),
            format!("{ours_fps:.0}"),
            format!("{ours_fpw:.1}"),
        ],
        vec![
            "BARVINN (paper)".into(),
            "1/2".into(),
            "250 MHz".into(),
            "2296".into(),
            "106.8".into(),
        ],
        vec![
            "FINN-R (paper)".into(),
            "1/2".into(),
            "178 MHz".into(),
            format!("{finn_fps:.0}"),
            "41.0".into(),
        ],
        vec![
            "FILM-QNN (model)".into(),
            "4(8)/5".into(),
            "150 MHz".into(),
            format!("{:.0}", film.fps),
            format!("{:.1}", film.fps_per_watt),
        ],
        vec![
            "FILM-QNN (paper)".into(),
            "4(8)/5".into(),
            "150 MHz".into(),
            "109".into(),
            "8.4".into(),
        ],
    ];
    report_table(
        "Table 6 — ResNet-50 on ImageNet",
        &["", "W/A", "clock", "FPS", "FPS/Watt"],
        &rows,
    );

    // FINN scalability observation (§4.2): the tuned ResNet-50 build uses
    // >87% of the U250, BARVINN stays at ~15% regardless of model size.
    let ours_util =
        resource_model::u250_lut_utilisation(&resource_model::overall_resources());
    let finn_util = finn_luts / resource_model::U250_LUTS as f64 * 100.0;
    println!(
        "\nU250 LUT utilisation: BARVINN {ours_util:.1}% (model-independent), \
         FINN-R ResNet-50 ≈ {finn_util:.0}% (paper: >87%)"
    );

    // Shape assertions.
    assert!(finn_fps > ours_fps, "FINN leads raw FPS");
    assert!(ours_fpw > 41.0, "BARVINN leads FPS/W over FINN-R");
    assert!(ours_fpw > film.fps_per_watt * 4.0, "FILM-QNN far behind in FPS/W");
    assert!(ours_fps > film.fps * 5.0, "FILM-QNN far behind in FPS");
    assert!(finn_util > 50.0, "FINN build dominates the device");
    assert!(ours_util < 20.0, "BARVINN footprint small + model-independent");
    println!("shape checks passed");
}
