//! Figure 5: Pipelined vs Distributed execution — the throughput/latency
//! trade measured on the cycle-accurate simulator (distributed) and with
//! the analytic model (both), plus the §2/§3.1.1 architecture-comparison
//! ablation (BitFusion / BitBlade / Loom).

use barvinn::accel::{System, SystemConfig, SystemExit};
use barvinn::codegen::{compile_distributed, EdgePolicy};
use barvinn::model::zoo::{self, resnet9_cifar10, Rng};
use barvinn::perf::benchkit::report_table;
use barvinn::perf::bitfusion::{bit_ops_per_mac, shifter_adder_cost, Arch};
use barvinn::perf::cycle_model::{
    fps_distributed, fps_pipelined_streamed, latency_cycles_distributed,
    latency_cycles_pipelined, Bits,
};
use barvinn::sim::Tensor3;
use barvinn::CLOCK_HZ;

fn main() {
    // --- analytic: both modes on ResNet9 -------------------------------------
    let net = zoo::NetShape {
        name: "resnet9-mid",
        convs: zoo::RESNET9_SCHEDULE
            .iter()
            .map(|&(_, ci, co, stride, in_h)| zoo::ConvShape {
                ci,
                co,
                k: 3,
                stride,
                pad: 1,
                in_h,
            })
            .collect(),
        fcs: vec![],
        quant_exempt: vec![],
    };
    let bits = Bits { w: 2, a: 2 };
    let fp = fps_pipelined_streamed(&net, bits, CLOCK_HZ);
    let fd = fps_distributed(&net, bits, CLOCK_HZ);
    let lp = latency_cycles_pipelined(&net, bits);
    let ld = latency_cycles_distributed(&net, bits);
    report_table(
        "Fig. 5 — execution modes on ResNet9 (2b/2b, analytic)",
        &["mode", "FPS @250MHz", "latency (cycles)", "latency (µs)"],
        &[
            vec![
                "Pipelined".into(),
                format!("{fp:.0}"),
                lp.to_string(),
                format!("{:.1}", lp as f64 / 250.0),
            ],
            vec![
                "Distributed".into(),
                format!("{fd:.0}"),
                ld.to_string(),
                format!("{:.1}", ld as f64 / 250.0),
            ],
        ],
    );
    assert!(fp > fd, "pipelined maximises throughput");
    assert!(ld < lp, "distributed minimises latency");

    // --- measured: distributed mode on the simulator (conv6) -----------------
    let m = resnet9_cifar10(2, 2);
    let layer = &m.layers[5];
    let plan = compile_distributed(layer, EdgePolicy::SkipEdges).expect("plan");
    let mut sys = System::new(SystemConfig::default());
    let mut rng = Rng(4);
    let input =
        Tensor3::from_fn(layer.ci, layer.in_h, layer.in_w, |_, _, _| rng.range_i32(0, 3));
    plan.load_into(&mut sys, layer, &input);
    let exit = sys.run();
    assert_eq!(exit, SystemExit::AllExited);
    let slowest = (0..8).map(|i| sys.mvus[i].busy_cycles()).max().unwrap();
    println!(
        "\nmeasured distributed conv6: total {} MVU cycles over 8 MVUs, \
         critical path {} (analytic latency {})",
        sys.total_mvu_busy_cycles(),
        slowest,
        plan.latency_cycles()
    );
    assert_eq!(slowest, plan.latency_cycles());

    // --- ablation: bit-flexible architecture comparison ----------------------
    let mut rows = Vec::new();
    for arch in [Arch::Barvinn, Arch::BitFusion, Arch::BitBlade, Arch::Loom] {
        let (vs, fs, at) = shifter_adder_cost(arch);
        rows.push(vec![
            format!("{arch:?}"),
            format!("{:.1}", bit_ops_per_mac(arch, Bits { w: 1, a: 1 })),
            format!("{:.1}", bit_ops_per_mac(arch, Bits { w: 2, a: 2 })),
            format!("{:.1}", bit_ops_per_mac(arch, Bits { w: 4, a: 4 })),
            format!("{vs}v+{fs}f"),
            at.to_string(),
        ]);
    }
    report_table(
        "Ablation — bit-flexible architectures (§2, §3.1.1)",
        &["arch", "bit-ops/MAC 1/1", "2/2", "4/4", "shifters", "adder trees"],
        &rows,
    );
    assert!(
        bit_ops_per_mac(Arch::Barvinn, Bits { w: 1, a: 1 })
            < bit_ops_per_mac(Arch::BitFusion, Bits { w: 1, a: 1 })
    );
    println!("mode + ablation checks passed");
}
