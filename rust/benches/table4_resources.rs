//! Table 4: post-synthesis resource utilisation — regenerated from the
//! calibrated analytic resource/power model, with paper values side by
//! side and tolerance assertions.

use barvinn::perf::benchkit::report_table;
use barvinn::perf::resource_model::{
    mvu_resources, overall_resources, pito_resources, u250_lut_utilisation,
};

fn main() {
    let pito = pito_resources();
    let one_mvu = mvu_resources(8 * 1024, 1024);
    let array = (0..8).fold(
        barvinn::perf::resource_model::Resources {
            lut: 0,
            bram36: 0,
            dsp: 0,
            dynamic_power_w: 0.0,
            clock_mhz: 250,
        },
        |acc, _| acc.add(one_mvu),
    );
    let overall = overall_resources();

    let row = |name: &str,
               r: &barvinn::perf::resource_model::Resources,
               paper: (u64, u64, u64, f64)| {
        vec![
            name.to_string(),
            r.lut.to_string(),
            paper.0.to_string(),
            r.bram36.to_string(),
            paper.1.to_string(),
            r.dsp.to_string(),
            paper.2.to_string(),
            format!("{:.3}", r.dynamic_power_w),
            format!("{:.3}", paper.3),
        ]
    };
    report_table(
        "Table 4 — resources (model vs paper), 250 MHz",
        &["", "LUT", "paper", "BRAM", "paper", "DSP", "paper", "W", "paper"],
        &[
            row("Pito RISC-V", &pito, (10_454, 15, 0, 0.410)),
            row("MVU array", &array, (190_625, 1_312, 512, 21.066)),
            row("Overall", &overall, (201_079, 1_327, 512, 21.504)),
        ],
    );
    println!(
        "\nU250 utilisation: {:.1}% LUTs (paper Table 5: 15.0%)",
        u250_lut_utilisation(&overall)
    );

    // Tolerances (constants are calibrated; structure does the scaling).
    assert_eq!(pito.lut, 10_454);
    assert!((array.lut as f64 / 190_625.0 - 1.0).abs() < 0.02);
    assert!((array.bram36 as f64 / 1_312.0 - 1.0).abs() < 0.05);
    assert_eq!(array.dsp, 512);
    assert!((overall.dynamic_power_w / 21.504 - 1.0).abs() < 0.05);
    println!("tolerance checks passed");
}
