//! Deep-model multi-pass bench: the 16-layer ResNet-18-style CIFAR stack
//! *executed* on the simulated array in two pipelined passes, reported
//! next to the analytic `perf::cycle_model` prediction (the Table-6-class
//! accounting that was previously analytic-only) — asserting the executed
//! and predicted cycle counts agree exactly, layer by layer, and that the
//! lap-sum throughput model matches the session's bottleneck accounting.

use barvinn::codegen::{compile_multi_pass, EdgePolicy};
use barvinn::model::zoo::{resnet18_cifar, Rng};
use barvinn::perf::benchkit::{bench, report_table};
use barvinn::perf::cycle_model::{self, Bits};
use barvinn::session::{ExecutionMode, SessionBuilder};
use barvinn::sim::Tensor3;
use barvinn::CLOCK_HZ;

fn main() {
    let m = resnet18_cifar(2, 2);
    let bits = Bits { w: 2, a: 2 };
    let net = cycle_model::shape_of_model("resnet18-cifar", &m);
    let predicted = cycle_model::layer_cycles(&net, bits);

    // SkipEdges = the paper's Table-3-style row accounting, which the
    // analytic conv model also uses: executed must equal predicted exactly.
    let mut session = SessionBuilder::new(m.clone())
        .mode(ExecutionMode::MultiPass)
        .edge_policy(EdgePolicy::SkipEdges)
        .build()
        .expect("compile deep model");
    let l0 = &m.layers[0];
    let mut rng = Rng(3);
    let input =
        Tensor3::from_fn(l0.ci, l0.in_h, l0.in_w, |_, _, _| rng.range_i32(0, 3));
    let out = session.run(&input).expect("multi-pass run");

    let mut rows = Vec::new();
    let mut executed_total = 0u64;
    for ((l, &want), &got) in m.layers.iter().zip(&predicted).zip(&out.mvu_cycles) {
        assert_eq!(got, want, "{}: executed != analytic", l.name);
        executed_total += got;
        rows.push(vec![
            l.name.clone(),
            format!("[{},{},{}]", l.ci, l.in_h, l.in_w),
            want.to_string(),
            got.to_string(),
        ]);
    }
    let predicted_total: u64 = predicted.iter().sum();
    assert_eq!(executed_total, predicted_total);
    assert_eq!(out.total_mvu_cycles, predicted_total);
    rows.push(vec![
        "total".into(),
        "".into(),
        predicted_total.to_string(),
        executed_total.to_string(),
    ]);
    report_table(
        "ResNet-18/CIFAR (16 layers, 2 passes) — analytic vs executed cycles (2b/2b)",
        &["layer", "input", "analytic", "executed"],
        &rows,
    );

    // Throughput: the lap-sum pipelined model (§3.1.6) must equal the
    // session's per-pass bottleneck accounting for one image.
    let lap_fps = cycle_model::fps_pipelined(&net, bits, CLOCK_HZ);
    let metrics = session.metrics();
    let session_fps = metrics.steady_state_fps_bound_at(CLOCK_HZ);
    let rel = (lap_fps - session_fps).abs() / lap_fps;
    assert!(
        rel < 1e-9,
        "lap model {lap_fps:.1} FPS vs session bottleneck {session_fps:.1} FPS"
    );
    // Streamed (work-conserving) steady state is the upper bound.
    let streamed_fps = cycle_model::fps_pipelined_streamed(&net, bits, CLOCK_HZ);
    assert!(streamed_fps >= lap_fps);

    // The multi-pass price: per-image weight/scaler/bias reload traffic.
    let plan = compile_multi_pass(&m, EdgePolicy::SkipEdges).unwrap();
    println!(
        "\n{} passes/image, {} RAM words reloaded/image (weight-reload cost of \
         run-time programmability)",
        plan.n_passes(),
        plan.reload_words()
    );
    println!(
        "lap-pipelined {lap_fps:.0} FPS, streamed bound {streamed_fps:.0} FPS at 250 MHz"
    );

    // Wall-clock of the executed multi-pass turbo path.
    let r = bench("deep multi-pass turbo run (16 layers)", 200, || {
        let o = session.run(&input).expect("run");
        assert_eq!(o.total_mvu_cycles, predicted_total);
    });
    println!(
        "  → {:.1} M MVU-cycles/s simulated",
        predicted_total as f64 / r.per_iter.as_secs_f64() / 1e6
    );
    println!("deep_multipass OK");
}
