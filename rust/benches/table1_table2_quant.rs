//! Tables 1 & 2: effect of quantization on model size (analytic, exact for
//! Table 2's Int2 row) and accuracy (substitution experiment: the LSQ demo
//! results written by `make artifacts` into artifacts/lsq_accuracy.json).

use barvinn::perf::benchkit::report_table;
use barvinn::perf::model_size::{
    fp32_bytes, fully_quantized_bytes, resnet9_original, resnet9_plain, table1_rows,
};

fn main() {
    // --- Table 2: ResNet9 sizes ---------------------------------------------
    let rows = vec![
        vec![
            "Original".into(),
            "Fp32".into(),
            fp32_bytes(&resnet9_original()).to_string(),
            "19605141".into(),
        ],
        vec![
            "Plain-CNN".into(),
            "Fp32".into(),
            fp32_bytes(&resnet9_plain()).to_string(),
            "18912487".into(),
        ],
        vec![
            "Quantized Plain-CNN".into(),
            "Int2".into(),
            fully_quantized_bytes(&resnet9_plain(), 2).to_string(),
            "1181360".into(),
        ],
    ];
    report_table(
        "Table 2 — ResNet9 model size (bytes, ours vs paper)",
        &["model", "precision", "ours", "paper"],
        &rows,
    );
    assert_eq!(fully_quantized_bytes(&resnet9_plain(), 2), 1_181_360, "exact");

    // --- Table 1: ResNet18 / SSD300 sizes ------------------------------------
    let paper_mb = [2.889, 5.559, 10.87, 42.8, 10.34, 11.81, 14.77, 32.49];
    let rows: Vec<Vec<String>> = table1_rows()
        .iter()
        .zip(&paper_mb)
        .map(|((model, prec, bytes), paper)| {
            vec![
                model.to_string(),
                prec.to_string(),
                format!("{:.3}", *bytes as f64 / 1e6),
                format!("{paper:.3}"),
            ]
        })
        .collect();
    report_table(
        "Table 1 — model sizes (MB, ours vs paper)",
        &["model", "precision", "ours", "paper"],
        &rows,
    );

    // --- Accuracy trend (substitution, DESIGN.md §4) --------------------------
    match std::fs::read_to_string("artifacts/lsq_accuracy.json") {
        Ok(src) => {
            let v = barvinn::model::json::parse(&src).expect("lsq json");
            let acc = v.get("accuracy").expect("accuracy");
            let rows: Vec<Vec<String>> = ["fp32", "8", "4", "2"]
                .iter()
                .map(|k| {
                    vec![
                        format!("LSQ({k})"),
                        format!(
                            "{:.3}",
                            acc.get(k).and_then(|x| x.as_f64()).unwrap_or(f64::NAN)
                        ),
                    ]
                })
                .collect();
            report_table(
                "Tables 1/2 accuracy trend — LSQ demo on synthetic 10-class images",
                &["precision", "accuracy"],
                &rows,
            );
            let fp32 = acc.get("fp32").and_then(|x| x.as_f64()).unwrap();
            let two = acc.get("2").and_then(|x| x.as_f64()).unwrap();
            assert!(
                two > fp32 - 0.10,
                "2-bit LSQ must stay within 10 points of fp32 (paper: 1–3%)"
            );
            println!("accuracy-trend check passed (2-bit within 10 pts of fp32)");
        }
        Err(_) => println!("(artifacts/lsq_accuracy.json missing — run `make artifacts`)"),
    }
}
