//! §Perf hot-path microbenchmarks: the MVU inner loop, the full pipelined
//! system (Pito + 8 MVUs) as a cold per-image rebuild vs a warm
//! weight-resident `InferenceSession`, the turbo vs cycle-accurate backend
//! split, the lap-worker `--threads 1..N` sweep over a streamed batch, the
//! crossbar, the assembler and the JSON model load — the profile targets
//! of EXPERIMENTS.md §Perf.
//!
//! Writes the machine-readable `BENCH_hotpath.json` report (schema
//! `barvinn.bench_hotpath/v1`, see docs/BENCH_SCHEMAS.md) that CI's
//! `perf-gate` job gates on; `--threads N` sets the sweep ceiling.

use barvinn::accel::{System, SystemConfig, SystemExit};
use barvinn::codegen::{compile_pipelined, EdgePolicy};
use barvinn::exec::ExecMode;
use barvinn::model::zoo::{resnet9_cifar10, Rng};
use barvinn::mvu::{kernel_variant, Mvu, MvuConfig, XbarWrite};
use barvinn::perf::benchkit::bench;
use barvinn::session::SessionBuilder;
use barvinn::sim::Tensor3;

/// Render a float as a JSON number; non-finite becomes `null` (the
/// library's `json_num` is crate-private, so the bench carries its own).
fn jnum(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // `--threads N`: sweep the streamed lap-worker knob over 1..=N.
    let max_threads: usize = args
        .iter()
        .position(|a| a == "--threads")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(4)
        .max(1);
    // --- MVU inner loop: one dense 512-input-channel conv row job ------------
    let m = resnet9_cifar10(2, 2);
    let l = &m.layers[7]; // conv8: 512→512
    {
        use barvinn::codegen::layout::{ActLayout, WeightLayout};
        let in_l = ActLayout {
            base: 0,
            h: l.in_h,
            w: l.in_w,
            pad: 1,
            pad_rows: false,
            cb: l.ci_blocks(),
            prec: l.aprec,
        };
        let out_l = ActLayout {
            base: 16384,
            h: l.out_h(),
            w: l.out_w(),
            pad: 0,
            pad_rows: false,
            cb: l.co_sets(),
            prec: l.oprec,
        };
        let w_l = WeightLayout {
            base: 0,
            cos: l.co_sets(),
            fh: 3,
            fw: 3,
            cb: l.ci_blocks(),
            prec: l.wprec,
        };
        let mut sys = System::new(SystemConfig::default());
        w_l.load(&mut sys.mvus[0].weights, &l.weights, l.ci, l.co);
        let jobs =
            barvinn::codegen::conv_jobs(l, &in_l, &out_l, &w_l, 0, 0, None, EdgePolicy::SkipEdges);
        let cycles: u64 = jobs.iter().map(|j| j.cycles()).sum();
        let r = bench("mvu: conv8 layer (18,432 cycles)", 2000, || {
            for j in &jobs {
                sys.run_job(0, j.clone()).unwrap();
            }
        });
        println!(
            "  → {:.1} M MVU-cycles/s",
            cycles as f64 / r.per_iter.as_secs_f64() / 1e6
        );
    }

    // --- full system: per-image rebuild (cold) vs warm session ---------------
    // The cold path is what every consumer hand-wired before the session
    // API existed: build the whole system and reload every weight RAM for
    // each image. The warm path compiles + loads once, then resets only
    // activation state per image. The block's tail carries the headline
    // numbers out for the BENCH_hotpath.json report below.
    let (cycle_ms_per_image, turbo_ms_per_image, speedup, cycles_per_frame, frame_mvu_cycles) = {
        let compiled = compile_pipelined(&m, EdgePolicy::PadInRam).expect("compile");
        let mut rng = Rng(2);
        let input = Tensor3::from_fn(64, 32, 32, |_, _, _| rng.range_i32(0, 3));
        let mut sys_cycles = 0;
        let cold = bench("system: rebuild+reload per image (cold)", 4000, || {
            let mut sys = System::new(SystemConfig::default());
            compiled.load_into(&mut sys, &input);
            assert_eq!(sys.run(), SystemExit::AllExited);
            sys_cycles = sys.cycles();
        });
        println!(
            "  → {:.1} M system-cycles/s ({} cycles/frame, {:.1} sim-frames/s)",
            sys_cycles as f64 / cold.per_iter.as_secs_f64() / 1e6,
            sys_cycles,
            1.0 / cold.per_iter.as_secs_f64()
        );

        let mut session = SessionBuilder::new(m.clone())
            .edge_policy(EdgePolicy::PadInRam)
            .exec_mode(ExecMode::CycleAccurate)
            .build()
            .expect("session");
        let warm = bench("session: warm cycle-accurate run()", 4000, || {
            let out = session.run(&input).expect("run");
            assert_eq!(out.system_cycles, sys_cycles, "warm run diverged from cold");
        });
        println!(
            "  → {:.1} M system-cycles/s ({:.1} sim-frames/s)",
            sys_cycles as f64 / warm.per_iter.as_secs_f64() / 1e6,
            1.0 / warm.per_iter.as_secs_f64()
        );
        println!(
            "  → warm session is {:.2}x the cold rebuild path \
             ({:.2} ms vs {:.2} ms per image)",
            cold.per_iter.as_secs_f64() / warm.per_iter.as_secs_f64(),
            warm.per_iter_ms(),
            cold.per_iter_ms()
        );

        // --- functional/timing split: turbo vs cycle-accurate, same image ----
        // Same warm session shape, same image; the only variable is the
        // execution backend. Outputs and per-layer job cycles must be
        // bit-identical (the proptest matrix enforces this exhaustively);
        // wall-clock is the headline — the ISSUE acceptance bar is ≥ 5×.
        let mut turbo_session = SessionBuilder::new(m.clone())
            .edge_policy(EdgePolicy::PadInRam)
            .exec_mode(ExecMode::Turbo)
            .build()
            .expect("turbo session");
        let cycle_out = session.run(&input).expect("cycle run");
        let turbo_out = turbo_session.run(&input).expect("turbo run");
        assert_eq!(turbo_out.output, cycle_out.output, "backends disagree on outputs");
        assert_eq!(
            turbo_out.mvu_cycles, cycle_out.mvu_cycles,
            "backends disagree on per-layer job cycles"
        );
        let turbo = bench("session: warm turbo run()", 4000, || {
            let out = turbo_session.run(&input).expect("turbo run");
            assert_eq!(out.total_mvu_cycles, cycle_out.total_mvu_cycles);
        });
        let speedup = warm.per_iter.as_secs_f64() / turbo.per_iter.as_secs_f64();
        println!(
            "  → turbo backend is {:.1}x the cycle-accurate path \
             ({:.3} ms vs {:.3} ms per image, bit-identical outputs)",
            speedup,
            turbo.per_iter_ms(),
            warm.per_iter_ms()
        );
        assert!(
            speedup >= 5.0,
            "turbo speedup regressed below the 5x acceptance bar: {speedup:.2}x"
        );
        (
            warm.per_iter_ms(),
            turbo.per_iter_ms(),
            speedup,
            sys_cycles,
            cycle_out.total_mvu_cycles,
        )
    };

    // --- lap-parallel streamed turbo: --threads 1..N sweep --------------------
    // Same streamed batch at every thread count; outputs, per-frame MVU
    // cycles and the pipeline books must be bit-identical to the
    // single-threaded run — only wall-clock is allowed to move.
    let mut rng = Rng(7);
    let l0 = &m.layers[0];
    let amax = l0.aprec.max_value();
    let stream_inputs: Vec<Tensor3> = (0..8)
        .map(|_| Tensor3::from_fn(l0.ci, l0.in_h, l0.in_w, |_, _, _| rng.range_i32(0, amax)))
        .collect();
    let mut baseline: Option<barvinn::session::StreamOutput> = None;
    let mut sweep: Vec<(usize, f64)> = Vec::new();
    for t in 1..=max_threads {
        let mut s = SessionBuilder::new(m.clone())
            .edge_policy(EdgePolicy::PadInRam)
            .exec_mode(ExecMode::Turbo)
            .threads(t)
            .build()
            .expect("streamed turbo session");
        let out = s.run_stream(&stream_inputs).expect("streamed batch");
        match &baseline {
            None => baseline = Some(out),
            Some(b) => {
                assert_eq!(
                    b.stream.pipeline_cycles, out.stream.pipeline_cycles,
                    "threads={t}: pipeline cycle books diverged from threads=1"
                );
                for (x, y) in b.outputs.iter().zip(&out.outputs) {
                    assert_eq!(x.output, y.output, "threads={t}: outputs diverged");
                    assert_eq!(
                        x.total_mvu_cycles, y.total_mvu_cycles,
                        "threads={t}: per-frame MVU cycles diverged"
                    );
                }
            }
        }
        let r = bench(&format!("session: streamed turbo x8 ({t} thread(s))"), 2000, || {
            let out = s.run_stream(&stream_inputs).expect("streamed batch");
            std::hint::black_box(out.stream.pipeline_cycles);
        });
        sweep.push((t, r.per_iter_ms() / stream_inputs.len() as f64));
    }
    if let Some((_, ms1)) = sweep.first() {
        let (tn, msn) = sweep.last().unwrap();
        println!(
            "  → {tn} thread(s) is {:.2}x the 1-thread streamed path \
             ({:.3} ms vs {:.3} ms per image, bit-identical)",
            ms1 / msn,
            msn,
            ms1
        );
    }

    // --- machine-readable report: BENCH_hotpath.json ---------------------------
    // bit-MACs/s: each busy MVU cycle retires 64 lanes × 64-bit words of
    // `acc ± popcnt(act & weight)` = 4096 bit-MACs.
    let bit_macs_per_s = frame_mvu_cycles as f64 * 4096.0 / (turbo_ms_per_image / 1e3);
    let sweep_json: Vec<String> = sweep
        .iter()
        .map(|(t, ms)| {
            format!(
                "{{\"threads\": {t}, \"ms_per_image\": {}, \"img_per_s\": {}}}",
                jnum(*ms),
                jnum(1e3 / ms)
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"schema\": \"barvinn.bench_hotpath/v1\",\n  \"model\": \"resnet9\",\n  \
         \"wbits\": 2,\n  \"abits\": 2,\n  \"images\": {},\n  \"cycles_per_frame\": {},\n  \
         \"kernel\": \"{}\",\n  \"threads_swept\": {},\n  \"cycle_ms_per_image\": {},\n  \
         \"turbo_ms_per_image\": {},\n  \"speedup\": {},\n  \"bit_macs_per_s\": {},\n  \
         \"sweep\": [{}]\n}}\n",
        stream_inputs.len(),
        cycles_per_frame,
        kernel_variant(),
        max_threads,
        jnum(cycle_ms_per_image),
        jnum(turbo_ms_per_image),
        jnum(speedup),
        jnum(bit_macs_per_s),
        sweep_json.join(", ")
    );
    std::fs::write("BENCH_hotpath.json", &json).expect("write BENCH_hotpath.json");
    println!("wrote BENCH_hotpath.json ({} kernel)", kernel_variant());

    // --- crossbar under full contention ---------------------------------------
    {
        let mut xb = barvinn::interconnect::Crossbar::new(8);
        let r = bench("xbar: 8 sources → 1 dest, 1k words", 1000, || {
            for s in 0..8 {
                xb.push(s, (0..128).map(|i| XbarWrite { dest_mask: 1, addr: i, word: i as u64 }));
            }
            while xb.busy() {
                xb.step();
            }
        });
        let _ = r;
    }

    // --- assembler throughput --------------------------------------------------
    {
        let compiled = compile_pipelined(&m, EdgePolicy::PadInRam).expect("compile");
        let asm = compiled.asm.clone();
        let r = bench("assembler: full pipelined program", 1000, || {
            let words = barvinn::pito::assemble(&asm).unwrap();
            std::hint::black_box(words);
        });
        let _ = r;
    }

    // --- standalone MVU step cost (idle + busy) ---------------------------------
    {
        let mut mvu = Mvu::new(0, MvuConfig::default());
        let r = bench("mvu: idle step x1e5", 500, || {
            for _ in 0..100_000 {
                std::hint::black_box(mvu.step());
            }
        });
        let _ = r;
    }
}
