//! Table 3: per-layer computation cost of 2b/2b ResNet9 on CIFAR10.
//! Regenerates every row by (a) the analytic model and (b) executing the
//! model through a SkipEdges-mode `InferenceSession` on **both** execution
//! backends (one warm run reports all eight layers at once — layer `i`
//! runs on MVU `i`), asserting the cycle counts are backend-invariant and
//! exactly equal to the paper (total 194,688). Also times the simulator.

use barvinn::accel::{System, SystemConfig};
use barvinn::codegen::layout::{ActLayout, WeightLayout};
use barvinn::codegen::{conv_jobs, layer_cycles, EdgePolicy};
use barvinn::exec::ExecMode;
use barvinn::model::zoo::{resnet9_cifar10, Rng};
use barvinn::perf::benchkit::{bench, report_table};
use barvinn::session::SessionBuilder;
use barvinn::sim::Tensor3;

fn main() {
    let m = resnet9_cifar10(2, 2);
    let paper = [34560u64, 34560, 17280, 32256, 16128, 27648, 13824, 18432];

    // One warm session per backend in Table-3-exact SkipEdges mode: the
    // per-MVU busy counters of a single run are exactly the per-layer
    // costs, and they must not depend on which backend executed the jobs.
    let mut rng = Rng(5);
    let input = Tensor3::from_fn(64, 32, 32, |_, _, _| rng.range_i32(0, 3));
    let mut session = SessionBuilder::new(m.clone())
        .edge_policy(EdgePolicy::SkipEdges)
        .exec_mode(ExecMode::CycleAccurate)
        .build()
        .expect("session");
    let out = session.run(&input).expect("run");
    let mut turbo_session = SessionBuilder::new(m.clone())
        .edge_policy(EdgePolicy::SkipEdges)
        .exec_mode(ExecMode::Turbo)
        .build()
        .expect("turbo session");
    let turbo_out = turbo_session.run(&input).expect("turbo run");
    assert_eq!(
        turbo_out.mvu_cycles, out.mvu_cycles,
        "Table-3 cycle counts must be backend-invariant"
    );
    assert_eq!(turbo_out.output, out.output, "backends disagree on outputs");

    let mut rows = Vec::new();
    let mut total_analytic = 0;
    let mut total_measured = 0;
    for ((l, &want), &measured) in m.layers.iter().zip(&paper).zip(&out.mvu_cycles) {
        let analytic = layer_cycles(l, EdgePolicy::SkipEdges);
        assert_eq!(analytic, want, "{} analytic", l.name);
        assert_eq!(measured, want, "{} measured", l.name);
        total_analytic += analytic;
        total_measured += measured;
        rows.push(vec![
            l.name.clone(),
            format!("[{},{},{}]", l.ci, l.in_h, l.in_w),
            format!("[{},{},3,3]", l.co, l.ci),
            want.to_string(),
            analytic.to_string(),
            measured.to_string(),
        ]);
    }
    rows.push(vec![
        "total".into(),
        "".into(),
        "".into(),
        "194688".into(),
        total_analytic.to_string(),
        total_measured.to_string(),
    ]);
    assert_eq!(total_analytic, 194_688);
    assert_eq!(total_measured, 194_688);
    assert_eq!(out.total_mvu_cycles, 194_688);
    report_table(
        "Table 3 — ResNet9/CIFAR10 per-layer cycles (2b/2b), paper vs ours",
        &["layer", "input", "kernel", "paper", "analytic", "simulated"],
        &rows,
    );

    // Simulator throughput on the heaviest layer (perf tracking; direct
    // drive isolates the MVU datapath from the CPU model).
    let l = &m.layers[0];
    let in_l = ActLayout {
        base: 0,
        h: l.in_h,
        w: l.in_w,
        pad: 1,
        pad_rows: false,
        cb: 1,
        prec: l.aprec,
    };
    let out_l = ActLayout {
        base: 16384,
        h: 32,
        w: 32,
        pad: 0,
        pad_rows: false,
        cb: 1,
        prec: l.oprec,
    };
    let w_l = WeightLayout { base: 0, cos: 1, fh: 3, fw: 3, cb: 1, prec: l.wprec };
    let mut sys = System::new(SystemConfig::default());
    w_l.load(&mut sys.mvus[0].weights, &l.weights, l.ci, l.co);
    let jobs = conv_jobs(l, &in_l, &out_l, &w_l, 0, 0, None, EdgePolicy::SkipEdges);
    let r = bench("simulate conv1 (34,560 MVU cycles)", 2000, || {
        for j in &jobs {
            sys.run_job(0, j.clone()).unwrap();
        }
    });
    println!(
        "simulator speed: {:.1} M MVU-cycles/s",
        34_560.0 / r.per_iter.as_secs_f64() / 1e6
    );
}
