//! Table 5: estimated CNV/CIFAR10 throughput, BARVINN vs FINN, across
//! W/A ∈ {1/1, 1/2, 2/2}.
//!
//! Our model brackets the paper's estimator between two bounds:
//! * **lower** — strict lap-sum pipelining (`fps_pipelined`): each lap of 8
//!   stages drains before the next starts;
//! * **upper** — work-conserving streaming (`fps_pipelined_streamed`).
//! The published numbers (61035/30517/15258) fall inside the bracket at
//! every precision point. Shape claims asserted: exact FPS halving per
//! bit-product doubling, BARVINN ahead of FINN in raw FPS, FINN ahead in
//! FPS/kLUT at 2/2 (using the conservative bound).

use barvinn::exec::ExecMode;
use barvinn::model::zoo;
use barvinn::perf::benchkit::report_table;
use barvinn::perf::{cycle_model, finn, resource_model};
use barvinn::session::{ExecutionMode, SessionBuilder};
use barvinn::sim::Tensor3;
use barvinn::CLOCK_HZ;

fn main() {
    let net = zoo::cnv_cifar10();
    let ours_klut = resource_model::overall_resources().lut as f64 / 1e3;

    // (W/A, paper ours FPS, FINN kLUT, paper FINN FPS)
    let points = [
        ("1/1", 61035.0, 28.2, 7716.0),
        ("1/2", 30517.0, 19.8, 2170.0),
        ("2/2", 15258.0, 24.3, 2170.0),
    ];

    let mut lo_fps = Vec::new();
    let mut hi_fps = Vec::new();
    let mut rows = Vec::new();
    for (wa, paper_ours, finn_klut, paper_finn) in points {
        let p: Vec<u8> = wa.split('/').map(|s| s.parse().unwrap()).collect();
        let bits = cycle_model::Bits { w: p[0], a: p[1] };
        let lo = cycle_model::fps_pipelined(&net, bits, CLOCK_HZ);
        let hi = cycle_model::fps_pipelined_streamed(&net, bits, CLOCK_HZ);
        let fb = finn::estimate_fps(&net, bits, finn_klut * 1e3);
        assert!(
            lo * 0.8 <= paper_ours && paper_ours <= hi * 1.2,
            "{wa}: paper {paper_ours} outside model bracket [{lo:.0}, {hi:.0}]"
        );
        lo_fps.push(lo);
        hi_fps.push(hi);
        rows.push(vec![
            wa.into(),
            format!("{lo:.0}–{hi:.0}"),
            format!("{paper_ours:.0}"),
            format!("{:.1}", lo / ours_klut),
            format!("{:.0}", fb.fps),
            format!("{paper_finn:.0}"),
            format!("{:.1}", fb.fps_per_klut),
        ]);
    }
    report_table(
        "Table 5 — CNV FPS: BARVINN vs FINN (model bracket | paper)",
        &["W/A", "ours (lo–hi)", "paper", "ours FPS/kLUT (lo)", "FINN", "paper", "FINN FPS/kLUT"],
        &rows,
    );

    // Shape assertions.
    assert!((lo_fps[0] / lo_fps[1] - 2.0).abs() < 1e-9, "1/1 = 2× 1/2");
    assert!((hi_fps[0] / hi_fps[2] - 4.0).abs() < 1e-9, "1/1 = 4× 2/2");
    for (i, &(wa, _, finn_klut, _)) in points.iter().enumerate() {
        let p: Vec<u8> = wa.split('/').map(|s| s.parse().unwrap()).collect();
        let bits = cycle_model::Bits { w: p[0], a: p[1] };
        let fb = finn::estimate_fps(&net, bits, finn_klut * 1e3);
        assert!(lo_fps[i] > fb.fps, "BARVINN leads raw FPS at {wa}");
    }
    // FINN leads FPS/kLUT at 2/2 (paper: 89.3 vs 75.8; conservative bound).
    let fb22 = finn::estimate_fps(&net, cycle_model::Bits { w: 2, a: 2 }, 24_300.0);
    assert!(
        fb22.fps_per_klut > lo_fps[2] / ours_klut,
        "FINN must lead FPS/kLUT at 2/2: {} vs {}",
        fb22.fps_per_klut,
        lo_fps[2] / ours_klut
    );
    println!(
        "\nshape checks passed: halving law, paper values inside the model\n\
         bracket, BARVINN FPS lead, FINN FPS/kLUT lead at 2/2"
    );

    // Backend invariance at every Table-5 precision point: the simulated
    // cycle counts behind the FPS scaling law must not depend on the
    // execution backend. One distributed-mode conv layer per (W/A) point,
    // run through both backends on the same input.
    for (w, a) in [(1u8, 1u8), (1, 2), (2, 2)] {
        let full = zoo::resnet9_cifar10(a, w);
        let mut layer = full.layers[5].clone(); // 256→256 conv
        layer.in_h = 8;
        layer.in_w = 8;
        let single = barvinn::model::Model {
            name: format!("table5-{w}w{a}a"),
            layers: vec![layer.clone()],
            host_prologue: None,
            host_epilogue: None,
        };
        let mut rng = zoo::Rng(42 + w as u64 * 8 + a as u64);
        let input = Tensor3::from_fn(layer.ci, layer.in_h, layer.in_w, |_, _, _| {
            rng.range_i32(0, layer.aprec.max_value())
        });
        let run = |exec: ExecMode| {
            let mut s = SessionBuilder::new(single.clone())
                .mode(ExecutionMode::Distributed)
                .exec_mode(exec)
                .build()
                .expect("session");
            s.run(&input).expect("run")
        };
        let cyc = run(ExecMode::CycleAccurate);
        let trb = run(ExecMode::Turbo);
        assert_eq!(
            trb.mvu_cycles, cyc.mvu_cycles,
            "{w}/{a}: per-MVU cycles must be backend-invariant"
        );
        assert_eq!(trb.output, cyc.output, "{w}/{a}: outputs must be backend-invariant");
        println!(
            "backend invariance {w}/{a}: {} MVU cycles on both backends",
            trb.total_mvu_cycles
        );
    }
}
