//! Figure 2: distribution of conv input-channel sizes across the model-zoo
//! census (the design justification for the 64-lane VVP). Prints the
//! histogram and the multiple-of-64 statistics (paper: 79%).

use barvinn::model::zoo::census_stats;
use barvinn::perf::benchkit::report_table;

fn main() {
    let s = census_stats();
    let total: usize = s.histogram.iter().map(|(_, n)| n).sum();
    let rows: Vec<Vec<String>> = s
        .histogram
        .iter()
        .map(|(b, n)| {
            let pct = *n as f64 / total as f64 * 100.0;
            let bar = "#".repeat((pct / 2.0) as usize);
            vec![b.to_string(), n.to_string(), format!("{pct:.1}%"), bar]
        })
        .collect();
    report_table(
        &format!(
            "Fig. 2 — channel sizes over {} models / {} conv layers",
            s.models, s.layers
        ),
        &["channels", "layers", "share", ""],
        &rows,
    );
    println!(
        "\nmultiples of 64: {:.1}% of layers, {:.1}% of models (paper: 79%)",
        s.layer_frac_mult64 * 100.0,
        s.model_frac_mult64 * 100.0
    );
    assert!(s.models >= 50);
    assert!(
        s.model_frac_mult64 > 0.55,
        "the census must reproduce the majority-of-64 conclusion"
    );
    println!("census checks passed");
}
