//! Multi-tenant fleet serving, end-to-end over real `InferenceSession`
//! engines: the PR-4 acceptance property.
//!
//! A mixed-precision workload (two `ModelKey` tenants of the same tiny
//! ResNet9-derived stack at different weight precisions) is served twice —
//! once with affinity routing, once with plain least-loaded routing — under
//! **both** execution backends. Affinity must perform strictly fewer
//! weight-RAM reload words (cold engine builds) than least-loaded, while
//! logits stay bit-identical across routing policies *and* backends: the
//! cache layer is a pure performance optimisation, invisible to numerics.
//!
//! Models are downscaled (6 layers, 16×16 inputs) so the cycle-accurate
//! legs stay responsive under `cargo test` in debug mode, mirroring the
//! session unit tests.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use barvinn::coordinator::{
    BatcherConfig, Engine, Fleet, FleetConfig, KeyedEngine, KeyedEngineFactory, MetricsSnapshot,
    ModelKey, RoutingPolicy,
};
use barvinn::exec::ExecMode;
use barvinn::model::zoo::{resnet9_cifar10, Rng};
use barvinn::model::Model;
use barvinn::perf::serve_bench::SessionEngine;
use barvinn::session::{ExecutionMode, SessionBuilder};

/// First six ResNet9 layers at 16×16 (same downscaling as the session unit
/// tests): full pipelined chain, debug-mode fast.
fn tiny_resnet9(a_bits: u8, w_bits: u8) -> Model {
    let mut m = resnet9_cifar10(a_bits, w_bits);
    m.layers.truncate(6);
    let mut h = 16;
    for l in &mut m.layers {
        l.in_h = h;
        l.in_w = h;
        if l.stride == 2 {
            h /= 2;
        }
    }
    m.validate().unwrap();
    m
}

/// Engine factory over the tiny model family: the key's precisions select
/// the quantization point, `reloads` records every cold build's RAM words
/// (ground truth the fleet's `reload_words_loaded` metric must match).
fn tiny_factory(
    exec: ExecMode,
    reloads: Arc<Mutex<HashMap<ModelKey, u64>>>,
) -> KeyedEngineFactory {
    Arc::new(move |key: &ModelKey| -> Result<KeyedEngine, String> {
        if key.model != "tiny9" {
            return Err(format!("unknown tenant {key}"));
        }
        let model = tiny_resnet9(key.abits, key.wbits);
        let session = SessionBuilder::new(model)
            .mode(key.mode)
            .exec_mode(exec)
            .build()
            .map_err(|e| e.to_string())?;
        let resident_words = session.resident_words();
        *reloads.lock().unwrap().entry(key.clone()).or_insert(0) += resident_words;
        Ok(KeyedEngine {
            engine: Box::new(SessionEngine::new(session)),
            resident_words,
        })
    })
}

/// Serve the canonical mixed-precision workload (2 tenants, `n` serialized
/// requests alternating in pairs: a a b b a a …) and return the per-request
/// logits, the total reload words cold builds paid, and the final metrics.
fn run_workload(
    exec: ExecMode,
    policy: RoutingPolicy,
    n: u64,
) -> (Vec<Vec<f32>>, u64, MetricsSnapshot) {
    let reloads = Arc::new(Mutex::new(HashMap::new()));
    let mut fleet = Fleet::new(
        tiny_factory(exec, Arc::clone(&reloads)),
        FleetConfig {
            workers: 2,
            // One warm engine per worker: an alternating two-tenant mix
            // thrashes without affinity, sticks with it.
            cache_per_worker: 1,
            batch: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
            policy,
            queue_depth: 0,
        },
    );
    let a = ModelKey::new("tiny9", 2, 2, ExecutionMode::Auto);
    let b = ModelKey::new("tiny9", 4, 2, ExecutionMode::Auto);
    let mut logits = Vec::new();
    for i in 0..n {
        let key = if (i / 2) % 2 == 0 { a.clone() } else { b.clone() };
        // Per-request deterministic image, independent of policy/backend
        // (activations are 2-bit for both tenants: codes 0..=3).
        let mut rng = Rng(0xF1EE7 + i);
        let img: Vec<f32> = (0..64 * 16 * 16).map(|_| rng.range_i32(0, 3) as f32).collect();
        // Serialized traffic: wait for each response so routing decisions
        // see settled cache state — the workload is fully deterministic.
        let resp = fleet
            .submit(key.clone(), img)
            .recv_timeout(Duration::from_secs(120))
            .expect("response");
        assert_eq!(resp.error, None, "request {i} failed");
        assert_eq!(resp.key, key);
        assert!(!resp.logits.is_empty());
        assert!(resp.sim_cycles > 0);
        logits.push(resp.logits);
    }
    let snap = fleet.metrics().snapshot();
    fleet.shutdown();
    let total_loaded: u64 = reloads.lock().unwrap().values().sum();
    assert_eq!(
        snap.reload_words_loaded, total_loaded,
        "metric must equal the factory-observed load words"
    );
    (logits, total_loaded, snap)
}

/// The acceptance criterion: ≥2 model keys, both exec backends — affinity
/// routing performs strictly fewer weight-RAM reloads than least-loaded
/// routing, with bit-identical logits.
#[test]
fn affinity_routing_saves_reloads_with_bit_identical_logits() {
    let n = 8;
    let mut logits_by_backend = Vec::new();
    for exec in [ExecMode::Turbo, ExecMode::CycleAccurate] {
        let (aff_logits, aff_loaded, aff_snap) = run_workload(exec, RoutingPolicy::Affinity, n);
        let (ll_logits, ll_loaded, ll_snap) = run_workload(exec, RoutingPolicy::LeastLoaded, n);

        assert_eq!(
            aff_logits, ll_logits,
            "{exec:?}: routing policy must be invisible to numerics"
        );
        assert!(
            aff_loaded < ll_loaded,
            "{exec:?}: affinity must reload strictly fewer weight-RAM words \
             (affinity {aff_loaded}, least-loaded {ll_loaded})"
        );
        // Affinity on a 2-tenant × 2-worker × 1-slot fleet: exactly one
        // cold build per tenant, everything else warm.
        assert_eq!(aff_snap.cache_misses, 2, "{exec:?}");
        assert_eq!(aff_snap.cache_hits, n - 2, "{exec:?}");
        assert!(aff_snap.reload_words_saved > 0, "{exec:?}");
        assert_eq!(aff_snap.completed, n, "{exec:?}");
        assert_eq!(ll_snap.completed, n, "{exec:?}");
        // Both tenants show up in per-key accounting with half the traffic.
        assert_eq!(aff_snap.per_key.len(), 2, "{exec:?}");
        for pk in &aff_snap.per_key {
            assert_eq!(pk.completed, n / 2, "{exec:?}: {}", pk.key);
            assert!(pk.sim_cycles > 0, "{exec:?}: {}", pk.key);
        }
        logits_by_backend.push(aff_logits);
    }
    // Backend equivalence end-to-end through the fleet: turbo and
    // cycle-accurate serve bit-identical logits.
    assert_eq!(
        logits_by_backend[0], logits_by_backend[1],
        "turbo and cycle-accurate fleets must serve identical logits"
    );
}

/// The PR-5 tentpole acceptance at fleet scale: a single-tenant pipelined
/// batch executes through the streamed pipeline (frames in flight across
/// the MVU stages) — ≥2× simulated throughput over the serial path under
/// **both** execution backends, with logits bit-identical to a serial
/// session run image by image (asserted here, not just benched).
#[test]
fn streamed_batches_double_throughput_with_identical_logits() {
    for exec in [ExecMode::Turbo, ExecMode::CycleAccurate] {
        let reloads = Arc::new(Mutex::new(HashMap::new()));
        let mut fleet = Fleet::new(
            tiny_factory(exec, Arc::clone(&reloads)),
            FleetConfig {
                workers: 1,
                cache_per_worker: 1,
                // One 6-frame key group = the 6-stage pipeline fully
                // occupied; the long wait keeps the batch whole.
                batch: BatcherConfig { max_batch: 6, max_wait: Duration::from_millis(500) },
                policy: RoutingPolicy::Affinity,
                queue_depth: 0,
            },
        );
        let key = ModelKey::new("tiny9", 2, 2, ExecutionMode::Auto);
        let imgs: Vec<Vec<f32>> = (0..6u64)
            .map(|i| {
                let mut rng = Rng(0xBEEF + i);
                (0..64 * 16 * 16).map(|_| rng.range_i32(0, 3) as f32).collect()
            })
            .collect();
        // Submit the whole batch before waiting so the batcher can form
        // one full key group.
        let rxs: Vec<_> =
            imgs.iter().map(|img| fleet.submit(key.clone(), img.clone())).collect();
        fleet.flush();
        let mut logits = Vec::new();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
            assert_eq!(resp.error, None, "{exec:?}: request {i}");
            logits.push(resp.logits);
        }
        let snap = fleet.metrics().snapshot();
        fleet.shutdown();

        assert_eq!(snap.streamed_frames, 6, "{exec:?}");
        let occ = snap.pipeline_occupancy();
        assert!(occ > 0.0 && occ <= 1.0, "{exec:?}: occupancy {occ}");
        let hz = barvinn::CLOCK_HZ;
        assert!(
            snap.sim_streamed_fps(hz) >= 2.0 * snap.sim_serial_fps(hz),
            "{exec:?}: streamed {:.0} FPS must be ≥2× serial {:.0} FPS",
            snap.sim_streamed_fps(hz),
            snap.sim_serial_fps(hz)
        );

        // Bit-identical to a serial session, frame by frame.
        let model = tiny_resnet9(2, 2);
        let mut serial = SessionBuilder::new(model.clone()).exec_mode(exec).build().unwrap();
        let l0 = &model.layers[0];
        let (ci, h, w) = (l0.ci, l0.in_h, l0.in_w);
        for (i, (img, got)) in imgs.iter().zip(&logits).enumerate() {
            let input = barvinn::sim::Tensor3 {
                c: ci,
                h,
                w,
                data: img.iter().map(|&v| v as i32).collect(),
            };
            let want: Vec<f32> =
                serial.run(&input).unwrap().output.data.iter().map(|&v| v as f32).collect();
            assert_eq!(got, &want, "{exec:?}: frame {i} logits differ from serial");
        }
    }
}

/// The two tenants really are different programs: same image, different
/// precision → different logits (guards against the workload degenerating
/// into one tenant twice, which would void the affinity comparison).
#[test]
fn tenants_differ_numerically() {
    let mut rng = Rng(0xF1EE7);
    let img: Vec<f32> = (0..64 * 16 * 16).map(|_| rng.range_i32(0, 3) as f32).collect();
    let run = |wbits: u8| -> Vec<f32> {
        let session = SessionBuilder::new(tiny_resnet9(2, wbits)).build().unwrap();
        let mut engine = SessionEngine::new(session);
        engine.infer_batch(std::slice::from_ref(&img)).remove(0).unwrap().0
    };
    assert_ne!(run(2), run(4));
}

/// First three ResNet9 layers at 8×8: the smallest model that still
/// pipelines, so the open-loop DES below can serve ~300 requests per
/// backend inside debug-mode `cargo test -q`.
fn micro9(a_bits: u8, w_bits: u8) -> Model {
    let mut m = resnet9_cifar10(a_bits, w_bits);
    m.layers.truncate(3);
    let mut h = 8;
    for l in &mut m.layers {
        l.in_h = h;
        l.in_w = h;
        if l.stride == 2 {
            h /= 2;
        }
    }
    m.validate().unwrap();
    m
}

/// Engine factory over the micro model family for the SLO bench: the
/// effective key's precisions select the quantization point, exactly as
/// the controller expects (degrade = same model, fewer weight bits).
fn micro_factory(exec: ExecMode) -> KeyedEngineFactory {
    Arc::new(move |key: &ModelKey| -> Result<KeyedEngine, String> {
        if key.model != "micro9" {
            return Err(format!("unknown tenant {key}"));
        }
        let session = SessionBuilder::new(micro9(key.abits, key.wbits))
            .mode(key.mode)
            .exec_mode(exec)
            .build()
            .map_err(|e| e.to_string())?;
        let resident_words = session.resident_words();
        Ok(KeyedEngine { engine: Box::new(SessionEngine::new(session)), resident_words })
    })
}

fn micro_shape(key: &ModelKey) -> Result<barvinn::perf::slo_bench::TenantShape, String> {
    let m = micro9(key.abits, key.wbits);
    let l0 = &m.layers[0];
    Ok(barvinn::perf::slo_bench::TenantShape {
        ci: l0.ci,
        h: l0.in_h,
        w: l0.in_w,
        amax: l0.aprec.max_value(),
    })
}

/// The PR-6 tentpole acceptance: under a ramped overload mix, the adaptive
/// policy holds windowed p99 ≤ target where the static policy breaches it,
/// throughput is ≥ the static policy's, and every response is bit-identical
/// to a serial `InferenceSession` run at whatever precision the controller
/// selected (no silent numeric drift); precision demonstrably restores to
/// full when load recedes — under both exec backends.
///
/// The ladder keeps activations at 2 bits on every rung so the input code
/// space is constant and degrading is purely a weight-precision (service
/// cost) knob — the paper's runtime precision programmability as a load
/// shedder that never drops a request. A long explicit dwell (12× the
/// calibrated cost) pins the trajectory: exactly one degrade inside the
/// overload phase, exactly one restore once load recedes.
#[test]
fn adaptive_precision_holds_slo_and_stays_bit_identical() {
    use barvinn::perf::serve_bench::MixEntry;
    use barvinn::perf::slo_bench::{run_slo_bench_with, RampPhase, SloBenchConfig};

    let nominal = ModelKey::new("micro9", 8, 2, ExecutionMode::Auto);
    let base_cfg = SloBenchConfig {
        seed: 11,
        workers: 1,
        cache_per_worker: 3,
        queue_depth: 0,
        max_batch: 2,
        mix: vec![MixEntry { key: nominal.clone(), weight: 1.0 }],
        ramp: vec![
            // Warm-up, 3× overload, then recede far below capacity.
            RampPhase { load: 0.4, count: 6 },
            RampPhase { load: 3.0, count: 24 },
            RampPhase { load: 0.15, count: 18 },
        ],
        ladder: vec![(8, 2), (2, 2)],
        window: 6,
        min_samples: 3,
        collect_responses: true,
        ..SloBenchConfig::default()
    };
    let n: u64 = base_cfg.ramp.iter().map(|p| p.count as u64).sum();

    let mut adaptive_json_by_backend = Vec::new();
    let mut adaptive_logits_by_backend = Vec::new();
    for exec in [ExecMode::Turbo, ExecMode::CycleAccurate] {
        let factory = micro_factory(exec);

        // Static baseline first: same driver, no controller — and the
        // calibrated per-image cost the adaptive dwell is pinned to.
        let stat_cfg = SloBenchConfig { adaptive: false, ..base_cfg.clone() };
        let stat = run_slo_bench_with(&stat_cfg, &factory, &micro_shape).unwrap();
        assert!(stat.base_cost > 0, "{exec:?}: calibration must cost cycles");
        assert_eq!(stat.degrades, 0, "{exec:?}: static run must never switch");
        assert_eq!((stat.completed, stat.failed, stat.shed), (n, 0, 0), "{exec:?}");

        let adaptive_cfg =
            SloBenchConfig { dwell: Some(12 * stat.base_cost), ..base_cfg.clone() };
        let run = run_slo_bench_with(&adaptive_cfg, &factory, &micro_shape).unwrap();
        assert_eq!((run.completed, run.failed, run.shed), (n, 0, 0), "{exec:?}");

        // Degrade under overload, restore to full precision when load
        // recedes — and the overload phase's tail p99 holds the target
        // where the static fleet breaches it.
        assert!(run.degrades >= 1, "{exec:?}: overload must trigger a degrade");
        assert!(run.restores >= 1, "{exec:?}: receding load must trigger a restore");
        assert_eq!(
            run.tenants[0].final_bits,
            (8, 2),
            "{exec:?}: precision must end restored to full"
        );
        assert!(
            stat.phases[1].tail_p99 > stat.p99_target,
            "{exec:?}: static must breach under 3× load (tail p99 {} ≤ target {})",
            stat.phases[1].tail_p99,
            stat.p99_target
        );
        assert!(
            run.phases[1].tail_p99 <= run.p99_target,
            "{exec:?}: adaptive must hold the target under 3× load (tail p99 {} > {})",
            run.phases[1].tail_p99,
            run.p99_target
        );
        // Throughput ≥ static: same completed count in no more virtual time.
        assert!(
            run.completed >= stat.completed && run.total_cycles <= stat.total_cycles,
            "{exec:?}: adaptive ({} in {} cy) must not trail static ({} in {} cy)",
            run.completed,
            run.total_cycles,
            stat.completed,
            stat.total_cycles
        );

        // Every response bit-identical to a serial session at the
        // controller-selected precision: no silent numeric drift.
        assert_eq!(run.responses.len() as u64, run.completed, "{exec:?}");
        let mut serials: HashMap<ModelKey, _> = HashMap::new();
        let mut degraded_seen = false;
        for (i, r) in run.responses.iter().enumerate() {
            degraded_seen |= r.key.wbits < nominal.wbits;
            let serial = serials.entry(r.key.clone()).or_insert_with(|| {
                SessionBuilder::new(micro9(r.key.abits, r.key.wbits))
                    .mode(r.key.mode)
                    .exec_mode(exec)
                    .build()
                    .unwrap()
            });
            let amax = micro_shape(&r.key).unwrap().amax;
            let input = barvinn::sim::Tensor3 {
                c: 64,
                h: 8,
                w: 8,
                // The engine's own quantizing front-end clamp.
                data: r.image.iter().map(|&v| (v as i32).clamp(0, amax)).collect(),
            };
            let want: Vec<f32> =
                serial.run(&input).unwrap().output.data.iter().map(|&v| v as f32).collect();
            assert_eq!(
                &r.logits, &want,
                "{exec:?}: response {i} ({}) drifts from the serial session",
                r.key
            );
        }
        assert!(degraded_seen, "{exec:?}: some responses must have served degraded");

        adaptive_json_by_backend.push(run.to_json());
        adaptive_logits_by_backend
            .push(run.responses.iter().map(|r| r.logits.clone()).collect::<Vec<_>>());
    }
    // The DES is driven by engine-reported cycles, which are contractually
    // backend-invariant: the whole report — trajectory, events, tails —
    // must be identical across turbo and cycle-accurate, logits included.
    assert_eq!(
        adaptive_json_by_backend[0], adaptive_json_by_backend[1],
        "turbo and cycle-accurate adaptive runs must produce identical reports"
    );
    assert_eq!(
        adaptive_logits_by_backend[0], adaptive_logits_by_backend[1],
        "turbo and cycle-accurate adaptive runs must serve identical logits"
    );
}

/// Release-only smoke of the full `bench-serve` pipeline over the real
/// zoo models (too heavy for debug-mode `cargo test -q`; CI additionally
/// runs the `barvinn bench-serve` binary in its serve-bench job).
#[test]
#[cfg(not(debug_assertions))]
fn bench_serve_pipeline_emits_valid_report() {
    use barvinn::perf::serve_bench::{parse_mix, run_bench, BenchConfig};
    let cfg = BenchConfig {
        seed: 7,
        images: 6,
        mix: parse_mix("resnet9:2:2=0.7,resnet9:4:4=0.3").unwrap(),
        ..Default::default()
    };
    let report = run_bench(&cfg).expect("bench runs");
    assert_eq!(report.completed, 6);
    assert_eq!(report.failed, 0);
    assert!(report.throughput_img_s > 0.0);
    assert!(report.p99_ms.is_finite());
    assert_eq!(report.streamed_frames, 6, "all frames execute via the streamed path");
    assert!(report.pipeline_occupancy > 0.0 && report.pipeline_occupancy <= 1.0);
    let json = report.to_json();
    assert!(json.contains("\"schema\": \"barvinn.bench_serve/v1\""));
    assert!(json.contains("\"pipeline_occupancy\""));
    assert!(!json.contains("null"), "no non-finite metrics in a healthy run");
}

/// The acceptance criterion on the real zoo: `bench-serve` with a
/// single-tenant pipelined mix at a fixed seed shows ≥2× simulated
/// throughput over the PR-4 serial path. Release-only (full 32×32
/// ResNet-9 batches); CI additionally gates the binary's report via jq.
#[test]
#[cfg(not(debug_assertions))]
fn bench_serve_single_tenant_pipelined_mix_doubles_throughput() {
    use barvinn::perf::serve_bench::{parse_mix, run_bench, BenchConfig};
    let cfg = BenchConfig {
        seed: 42,
        images: 16,
        workers: 1,
        cache_per_worker: 1,
        mix: parse_mix("resnet9:2:2=1").unwrap(),
        batch: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(500) },
        ..Default::default()
    };
    let report = run_bench(&cfg).expect("bench runs");
    assert_eq!(report.failed, 0);
    assert_eq!(report.streamed_frames, 16);
    assert!(
        report.sim_streamed_fps >= 2.0 * report.sim_serial_fps,
        "streamed {:.0} FPS must be ≥2× serial {:.0} FPS (occupancy {:.2})",
        report.sim_streamed_fps,
        report.sim_serial_fps,
        report.pipeline_occupancy
    );
}

/// The PR-10 acceptance criterion on the real zoo: continuous admission
/// (`--continuous`: engines serve one open pipeline per (worker, key),
/// flush boundaries become admission points) on the balanced `pipe8`
/// model approaches full occupancy and strictly beats the closed-batch
/// baseline at the same seed and mix — fill is paid once per stream,
/// the drain books only at close, and the steady share dominates.
/// Release-only; CI additionally gates the binary's reports via jq in
/// the serve-bench job.
#[test]
#[cfg(not(debug_assertions))]
fn bench_serve_continuous_admission_approaches_full_occupancy() {
    use barvinn::perf::serve_bench::{parse_mix, run_bench, BenchConfig};
    let base = BenchConfig {
        seed: 42,
        images: 16,
        workers: 1,
        cache_per_worker: 2,
        mix: parse_mix("pipe8:2:2=0.6,pipe8:4:4=0.4").unwrap(),
        batch: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(500) },
        ..Default::default()
    };
    let closed = run_bench(&base).expect("closed baseline runs");
    let cont =
        run_bench(&BenchConfig { continuous: true, ..base.clone() }).expect("continuous runs");
    for r in [&closed, &cont] {
        assert_eq!(r.failed, 0);
        assert_eq!(r.streamed_frames, 16, "every frame executes via the streamed path");
    }
    assert!(!closed.continuous && cont.continuous, "reports echo the admission mode");
    assert!(
        cont.pipeline_occupancy >= 0.9,
        "open-pipeline occupancy {:.3} must approach 1.0 on a balanced model",
        cont.pipeline_occupancy
    );
    assert!(
        cont.pipeline_occupancy > closed.pipeline_occupancy,
        "continuous occupancy {:.3} must beat the closed baseline's {:.3}",
        cont.pipeline_occupancy,
        closed.pipeline_occupancy
    );
    assert!(cont.p99_ms.is_finite(), "bounded tail under sustained admission");
    assert!(
        cont.steady_occupancy > closed.steady_occupancy,
        "fill paid once per stream: steady share {:.3} must beat per-flush {:.3}",
        cont.steady_occupancy,
        closed.steady_occupancy
    );
}
