//! Integration: a deep (>8-layer) model through multi-pass pipelined
//! scheduling (§3.1.6 "laps") end-to-end.
//!
//! The 16-layer `zoo::resnet18_cifar` stack — previously representable
//! only as an analytic `NetShape` — compiles to two pipelined passes and
//! *executes* on the simulated array through the unified
//! `InferenceSession`, bit-exactly against the Rust golden model under
//! both execution backends, with per-layer cycle accounting matching the
//! analytic `perf::cycle_model` prediction.
//!
//! Heavy paths are release-only (`cargo test --release`); under debug they
//! downscale spatially to keep `cargo test` responsive.

use barvinn::accel::{System, SystemConfig};
use barvinn::codegen::{compile_multi_pass, EdgePolicy};
use barvinn::exec::ExecMode;
use barvinn::model::zoo::{resnet18_cifar, Rng};
use barvinn::model::Model;
use barvinn::perf::cycle_model;
use barvinn::session::{ExecutionMode, SessionBuilder, SessionError};
use barvinn::sim::Tensor3;

fn golden_forward(model: &Model, input: &Tensor3) -> Tensor3 {
    model.golden_forward(input)
}

fn model_under_test() -> Model {
    let mut m = resnet18_cifar(2, 2);
    if cfg!(debug_assertions) {
        // Downscale spatially (keeps all 16 layers + channel widths).
        let mut h = 16;
        for l in &mut m.layers {
            l.in_h = h;
            l.in_w = h;
            if l.stride == 2 {
                h /= 2;
            }
        }
    }
    m.validate().unwrap();
    m
}

fn random_input(m: &Model, seed: u64) -> Tensor3 {
    let l0 = &m.layers[0];
    let mut rng = Rng(seed);
    Tensor3::from_fn(l0.ci, l0.in_h, l0.in_w, |_, _, _| rng.range_i32(0, 3))
}

/// The tentpole acceptance test: a >8-layer model compiles and runs
/// end-to-end through `InferenceSession` in both exec backends, matching
/// `sim::golden` bit-for-bit, cycles included.
#[test]
fn deep_model_multi_pass_bit_exact_both_backends() {
    let m = model_under_test();
    assert!(m.layers.len() > 8, "must exceed the array");
    let input = random_input(&m, 2026);
    let golden = golden_forward(&m, &input);
    let analytic: Vec<u64> = m
        .layers
        .iter()
        .map(|l| barvinn::codegen::layer_cycles(l, EdgePolicy::PadInRam))
        .collect();

    let mut per_backend = Vec::new();
    for exec in [ExecMode::Turbo, ExecMode::CycleAccurate] {
        let mut session = SessionBuilder::new(m.clone())
            .mode(ExecutionMode::Auto)
            .edge_policy(EdgePolicy::PadInRam)
            .exec_mode(exec)
            .build()
            .unwrap();
        assert_eq!(session.execution_mode(), ExecutionMode::MultiPass);
        assert_eq!(session.n_passes(), 2, "16 layers → 2 passes of 8");
        let out = session.run(&input).unwrap();
        assert_eq!(out.exec, exec);
        assert_eq!(out.output, golden, "{exec:?}: accelerator != golden");
        assert_eq!(out.mvu_cycles, analytic, "{exec:?}: per-layer cycles");
        assert_eq!(out.total_mvu_cycles, analytic.iter().sum::<u64>(), "{exec:?}");
        per_backend.push(out);
    }
    // Cross-backend: outputs and job-cycle accounting bit-identical.
    assert_eq!(per_backend[0].output, per_backend[1].output);
    assert_eq!(per_backend[0].mvu_cycles, per_backend[1].mvu_cycles);
}

/// Warm multi-pass reuse: the per-pass weight rotation must leave the
/// session bit-exact across several images.
#[test]
fn deep_session_reuse_stays_bit_exact() {
    let m = model_under_test();
    let mut session = SessionBuilder::new(m.clone())
        .mode(ExecutionMode::Auto)
        .build()
        .unwrap();
    for seed in [7u64, 8, 9] {
        let input = random_input(&m, seed);
        let out = session.run(&input).unwrap();
        assert_eq!(out.output, golden_forward(&m, &input), "seed {seed}");
    }
    let metrics = session.metrics();
    assert_eq!(metrics.images, 3);
    assert!(metrics.total_bottleneck_cycles <= metrics.total_mvu_cycles);
    assert!(metrics.serial_fps_at(barvinn::CLOCK_HZ) > 0.0);
}

/// Executed multi-pass cycles reproduce the analytic `cycle_model`
/// prediction exactly under the paper's SkipEdges (Table-3-style)
/// accounting — the Table-6-class deep-model claim, executed rather than
/// analytic. Release-only: full 32×32 scale.
#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: full-scale measured run")]
fn deep_model_executed_cycles_match_cycle_model() {
    let m = resnet18_cifar(2, 2);
    let predicted = cycle_model::total_cycles(
        &cycle_model::shape_of_model("resnet18-cifar", &m),
        cycle_model::Bits { w: 2, a: 2 },
    );
    let mut session = SessionBuilder::new(m.clone())
        .mode(ExecutionMode::MultiPass)
        .edge_policy(EdgePolicy::SkipEdges)
        .build()
        .unwrap();
    let out = session.run(&random_input(&m, 1)).unwrap();
    assert_eq!(out.total_mvu_cycles, predicted, "executed != analytic");
    // The lap-sum throughput model agrees with the session's bottleneck
    // accounting for a single image.
    let plan = compile_multi_pass(&m, EdgePolicy::SkipEdges).unwrap();
    assert_eq!(out.total_mvu_cycles, plan.total_analytic_cycles());
}

/// Typed-error surface at integration level: starved fuel and malformed
/// jobs both fail typed — never a panic, never a process abort.
#[test]
fn deep_session_errors_surface_typed() {
    let m = model_under_test();
    let mut starved = SessionBuilder::new(m.clone())
        .mode(ExecutionMode::Auto)
        .fuel(200)
        .build()
        .unwrap();
    match starved.run(&random_input(&m, 1)) {
        Err(SessionError::FuelExhausted { fuel: 200 }) => {}
        other => panic!("expected FuelExhausted, got {:?}", other.map(|o| o.image_index)),
    }

    // Malformed job config through the direct-drive path: typed, both
    // backends (the acceptance regression for the old panic).
    for exec in [ExecMode::CycleAccurate, ExecMode::Turbo] {
        let mut sys = System::new(SystemConfig { exec, ..Default::default() });
        let plan = compile_multi_pass(&model_under_test(), EdgePolicy::PadInRam).unwrap();
        let mut bad = plan.passes[0].plans[0].jobs[0].clone();
        bad.tiles = 0;
        let err = sys.run_job(0, bad).unwrap_err();
        assert!(
            matches!(err, barvinn::exec::TurboError::BadConfig { mvu: 0, .. }),
            "{exec:?}: {err}"
        );
        assert!(err.to_string().contains("bad job config"), "{exec:?}: {err}");
    }
}
