//! Randomized cross-module property tests (proptest-style; driven by the
//! crate-local deterministic RNG since proptest is not in the offline
//! vendor set). Each test sweeps many random cases of the *whole* path —
//! random layer geometry → layout → job generation → cycle-accurate
//! simulation → golden integer reference.

use barvinn::accel::{System, SystemConfig};

/// Case-count override for the nightly profiling job: when
/// `BARVINN_PROPTEST_CASES` is set (and parses), it replaces the built-in
/// per-profile default so the same properties sweep a much larger random
/// space than PR CI affords.
fn proptest_cases(default: u64) -> u64 {
    std::env::var("BARVINN_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}
use barvinn::codegen::layout::{load_scaler_bias, ActLayout, WeightLayout};
use barvinn::codegen::{conv_jobs, layer_cycles, EdgePolicy};
use barvinn::model::zoo::Rng;
use barvinn::model::{ConvLayer, QuantSpec};
use barvinn::quant::{pack_block, unpack_block, BitTensor, Precision, QuantSerCfg};
use barvinn::sim::{conv2d_i32, requant_i32, Tensor3};

fn random_layer(rng: &mut Rng, case: u64) -> ConvLayer {
    let ci = [64usize, 80, 128, 192][(rng.next_u64() % 4) as usize];
    let co = [64usize, 70, 128][(rng.next_u64() % 3) as usize];
    let stride = 1 + (rng.next_u64() % 2) as usize;
    let in_h = 4 + (rng.next_u64() % 5) as usize; // 4..=8
    let a_bits = 1 + (rng.next_u64() % 3) as u8; // 1..=3
    let w_bits = 1 + (rng.next_u64() % 3) as u8;
    let wprec = Precision::s(w_bits.max(1));
    ConvLayer {
        name: format!("prop{case}"),
        ci,
        co,
        fh: 3,
        fw: 3,
        stride,
        pad: 1,
        in_h,
        in_w: in_h,
        aprec: Precision::u(a_bits),
        wprec,
        oprec: Precision::u(a_bits),
        relu: rng.next_u64() % 2 == 0,
        weights: (0..co * ci * 9)
            .map(|_| rng.range_i32(wprec.min_value(), wprec.max_value()))
            .collect(),
        quant: QuantSpec {
            scale: (0..co).map(|_| rng.range_i32(1, 5) as u16).collect(),
            bias: (0..co).map(|_| rng.range_i32(-100, 100)).collect(),
            quant_msb: 10 + (rng.next_u64() % 6) as u8,
        },
    }
}

/// The big one: random conv layers end-to-end on the simulator vs golden.
#[test]
fn random_conv_layers_match_golden() {
    let mut rng = Rng(0xDEC0DE);
    let cases = if cfg!(debug_assertions) { 8 } else { 24 };
    for case in 0..cases {
        let layer = random_layer(&mut rng, case);
        let policy = if rng.next_u64() % 2 == 0 {
            EdgePolicy::PadInRam
        } else {
            EdgePolicy::SkipEdges
        };
        if layer.full_rows() == 0 {
            continue;
        }
        let in_l = ActLayout {
            base: 0,
            h: layer.in_h,
            w: layer.in_w,
            pad: 1,
            pad_rows: policy == EdgePolicy::PadInRam,
            cb: layer.ci_blocks(),
            prec: layer.aprec,
        };
        let out_l = ActLayout {
            base: 16384,
            h: layer.out_h(),
            w: layer.out_w(),
            pad: 0,
            pad_rows: false,
            cb: layer.co_sets(),
            prec: layer.oprec,
        };
        let w_l = WeightLayout {
            base: 0,
            cos: layer.co_sets(),
            fh: 3,
            fw: 3,
            cb: layer.ci_blocks(),
            prec: layer.wprec,
        };
        let mut sys = System::new(SystemConfig::default());
        let input = Tensor3::from_fn(layer.ci, layer.in_h, layer.in_w, |_, _, _| {
            rng.range_i32(0, layer.aprec.max_value())
        });
        in_l.load(&mut sys.mvus[0].act, &input);
        w_l.load(&mut sys.mvus[0].weights, &layer.weights, layer.ci, layer.co);
        load_scaler_bias(&mut sys.mvus[0], 0, &layer.quant.scale, &layer.quant.bias);

        let jobs = conv_jobs(&layer, &in_l, &out_l, &w_l, 0, 0, None, policy);
        let measured: u64 = jobs.into_iter().map(|j| sys.run_job(0, j).unwrap()).sum();
        assert_eq!(measured, layer_cycles(&layer, policy), "case {case} cycles");

        let got = out_l.read(&sys.mvus[0].act, layer.co);
        let acc = conv2d_i32(&input, &layer.weights, layer.spec());
        let want = requant_i32(
            &acc,
            &layer.quant.scale,
            &layer.quant.bias,
            QuantSerCfg {
                msb_index: layer.quant.quant_msb,
                out_bits: layer.oprec.bits,
                saturate: true,
            },
            layer.relu,
        );
        let r0 = barvinn::codegen::conv2d::global_row(&layer, policy, 0);
        let rows = barvinn::codegen::conv2d::rows_computed(&layer, policy);
        for c in 0..layer.co {
            for y in r0..r0 + rows {
                for x in 0..layer.out_w() {
                    assert_eq!(
                        got.get(c, y, x),
                        want.get(c, y, x),
                        "case {case} ({policy:?}) c={c} y={y} x={x}"
                    );
                }
            }
        }
    }
}

/// Bit-plane pack/unpack roundtrip over random precisions and values.
#[test]
fn random_bitplane_roundtrips() {
    let mut rng = Rng(0xB17);
    for _ in 0..500 {
        let bits = 1 + (rng.next_u64() % 16) as u8;
        let signed = rng.next_u64() % 2 == 0 && bits >= 2;
        let prec = Precision { bits, signed };
        let vals: [i32; 64] = std::array::from_fn(|_| {
            rng.range_i32(prec.min_value(), prec.max_value())
        });
        assert_eq!(unpack_block(&pack_block(&vals, prec), prec), vals);
    }
    // Multi-block tensors too.
    for _ in 0..50 {
        let bits = 1 + (rng.next_u64() % 8) as u8;
        let prec = Precision::u(bits);
        let n = 64 * (1 + (rng.next_u64() % 5) as usize);
        let vals: Vec<i32> = (0..n).map(|_| rng.range_i32(0, prec.max_value())).collect();
        assert_eq!(BitTensor::pack(&vals, prec).unpack(), vals);
    }
}

/// Layout image/read roundtrip over random geometries.
#[test]
fn random_act_layout_roundtrips() {
    let mut rng = Rng(0x1A10);
    for _ in 0..60 {
        let c = 1 + (rng.next_u64() % 200) as usize;
        let h = 1 + (rng.next_u64() % 8) as usize;
        let w = 1 + (rng.next_u64() % 8) as usize;
        let bits = 1 + (rng.next_u64() % 4) as u8;
        let l = ActLayout {
            base: (rng.next_u64() % 100) as u32,
            h,
            w,
            pad: (rng.next_u64() % 2) as usize,
            pad_rows: rng.next_u64() % 2 == 0,
            cb: c.div_ceil(64),
            prec: Precision::u(bits),
        };
        let t = Tensor3::from_fn(c, h, w, |_, _, _| rng.range_i32(0, (1 << bits) - 1));
        let mut ram = barvinn::mvu::ActRam::new((l.base + l.size_words()) as usize);
        l.load(&mut ram, &t);
        assert_eq!(l.read(&ram, c), t);
    }
}

/// Fault injection: flipping any single weight bit must change some output
/// (the simulator genuinely reads every weight plane it is billed for).
#[test]
fn weight_bit_flip_changes_output() {
    let mut rng = Rng(0xFA11);
    let layer = ConvLayer {
        name: "fault".into(),
        ci: 64,
        co: 64,
        fh: 3,
        fw: 3,
        stride: 1,
        pad: 1,
        in_h: 4,
        in_w: 4,
        aprec: Precision::u(2),
        wprec: Precision::s(2),
        // Full-width window (msb 15, 16 bits, shift 0) with a centring bias
        // keeps every accumulator inside the unclamped region, so *any*
        // accumulator change is visible in the output.
        oprec: Precision::u(16),
        relu: false,
        weights: (0..64 * 64 * 9).map(|_| rng.range_i32(-2, 1)).collect(),
        quant: QuantSpec {
            scale: vec![1; 64],
            bias: vec![8192; 64],
            quant_msb: 15,
        },
    };
    let in_l = ActLayout {
        base: 0,
        h: 4,
        w: 4,
        pad: 1,
        pad_rows: true,
        cb: 1,
        prec: layer.aprec,
    };
    let out_l = ActLayout {
        base: 16384,
        h: 4,
        w: 4,
        pad: 0,
        pad_rows: false,
        cb: 1,
        prec: layer.oprec,
    };
    let w_l = WeightLayout { base: 0, cos: 1, fh: 3, fw: 3, cb: 1, prec: layer.wprec };
    let input = Tensor3::from_fn(64, 4, 4, |_, _, _| rng.range_i32(1, 3));

    let run = |weights: &[i32]| -> Tensor3 {
        let mut sys = System::new(SystemConfig::default());
        in_l.load(&mut sys.mvus[0].act, &input);
        w_l.load(&mut sys.mvus[0].weights, weights, 64, 64);
        load_scaler_bias(&mut sys.mvus[0], 0, &layer.quant.scale, &layer.quant.bias);
        for j in conv_jobs(&layer, &in_l, &out_l, &w_l, 0, 0, None, EdgePolicy::PadInRam) {
            sys.run_job(0, j.clone()).unwrap();
        }
        out_l.read(&sys.mvus[0].act, 64)
    };

    let base = run(&layer.weights);
    for _ in 0..10 {
        let idx = (rng.next_u64() % layer.weights.len() as u64) as usize;
        let mut mutated = layer.weights.clone();
        // Flip between two representable values.
        mutated[idx] = if mutated[idx] == 1 { -2 } else { mutated[idx] + 1 };
        let out = run(&mutated);
        assert_ne!(base, out, "flipping weight {idx} must perturb the output");
    }
}

/// The functional/timing-split acceptance property: the job-level turbo
/// executor and the cycle-accurate stepper agree *bit-for-bit* on every
/// output word (self-RAM and crossbar destinations) and on every reported
/// job cycle count, across randomized precisions (1–8 bit
/// weights/activations, signed/unsigned), tile counts, pooling windows,
/// scaler/bias/ReLU enables and output destinations — with the
/// plain-integer `sim::golden` model as the third reference.
#[test]
fn turbo_and_cycle_accurate_backends_agree() {
    use barvinn::exec::ExecMode;
    use barvinn::mvu::{AguCfg, JobConfig, OutputDest};
    use barvinn::quant::pack_block;

    const OUT_BASE: u32 = 8000;
    let mut rng = Rng(0x7EB0);
    let cases = if cfg!(debug_assertions) { 48 } else { 160 };
    for case in 0..cases {
        // --- random job geometry ------------------------------------------
        let ab = 1 + (rng.next_u64() % 8) as u8;
        let wb = 1 + (rng.next_u64() % 8) as u8;
        let aprec = Precision { bits: ab, signed: ab >= 2 && rng.next_u64() % 2 == 0 };
        let wprec = Precision { bits: wb, signed: wb >= 2 && rng.next_u64() % 2 == 0 };
        let tiles = 1 + (rng.next_u64() % 4) as u32;
        let pool_count = [1u32, 2, 4][(rng.next_u64() % 3) as usize];
        let outputs = pool_count * (1 + (rng.next_u64() % 3) as u32);
        let combos = ab as u32 * wb as u32;
        let scaler_en = rng.next_u64() % 2 == 0;
        let bias_en = rng.next_u64() % 2 == 0;
        let relu_en = rng.next_u64() % 2 == 0;
        let out_bits = 1 + (rng.next_u64() % 16) as u8;
        let quant = QuantSerCfg {
            msb_index: (out_bits - 1) + (rng.next_u64() % 8) as u8,
            out_bits,
            saturate: rng.next_u64() % 2 == 0,
        };
        // Crossbar destinations exclude the source MVU: turbo batches a
        // job's traffic at completion, so mid-job self-delivery (which no
        // generated workload performs) is outside the equivalence contract.
        let dest = if rng.next_u64() % 2 == 0 {
            OutputDest::SelfRam
        } else {
            OutputDest::Xbar { dest_mask: 1u8 << (1 + (rng.next_u64() % 7) as u8) }
        };

        // --- random operands ----------------------------------------------
        // Activations: `outputs × tiles` distinct blocks laid out linearly;
        // weights: `tiles` 64×64 tiles shared by every output.
        let a_vals: Vec<[i32; 64]> = (0..(outputs * tiles) as usize)
            .map(|_| {
                std::array::from_fn(|_| rng.range_i32(aprec.min_value(), aprec.max_value()))
            })
            .collect();
        let w_vals: Vec<[[i32; 64]; 64]> = (0..tiles as usize)
            .map(|_| {
                std::array::from_fn(|_| {
                    std::array::from_fn(|_| rng.range_i32(wprec.min_value(), wprec.max_value()))
                })
            })
            .collect();
        let scales: Vec<[u16; 64]> = (0..outputs as usize)
            .map(|_| std::array::from_fn(|_| rng.range_i32(1, 6) as u16))
            .collect();
        let biases: Vec<[i32; 64]> = (0..outputs as usize)
            .map(|_| std::array::from_fn(|_| rng.range_i32(-500, 500)))
            .collect();

        let cfg = JobConfig {
            aprec,
            wprec,
            tiles,
            outputs,
            // Per output: `tiles` blocks, replayed `combos` times, then
            // advance to the next output's blocks.
            a_agu: AguCfg::from_strides(
                0,
                &[
                    (tiles - 1, ab as i64),
                    (combos - 1, 0),
                    (outputs - 1, (tiles * ab as u32) as i64),
                ],
            ),
            // One full pass = one output; the AGU wraps for the replay.
            w_agu: AguCfg::from_strides(0, &[(tiles - 1, wb as i64), (combos - 1, 0)]),
            s_agu: AguCfg::from_strides(0, &[(outputs - 1, 1)]),
            b_agu: AguCfg::from_strides(0, &[(outputs - 1, 1)]),
            o_agu: AguCfg::from_strides(
                OUT_BASE,
                &[(outputs / pool_count - 1, out_bits as i64)],
            ),
            scaler_en,
            bias_en,
            relu_en,
            pool_count,
            quant,
            dest,
        };

        // --- identically-loaded systems, one per backend -------------------
        let load = |sys: &mut System| {
            for (b, vals) in a_vals.iter().enumerate() {
                sys.mvus[0].act.load((b * ab as usize) as u32, &pack_block(vals, aprec));
            }
            for (t, tile) in w_vals.iter().enumerate() {
                let rows: Vec<Vec<u64>> = tile.iter().map(|r| pack_block(r, wprec)).collect();
                let words: Vec<[u64; 64]> = (0..wb as usize)
                    .map(|p| std::array::from_fn(|r| rows[r][p]))
                    .collect();
                sys.mvus[0].weights.load((t * wb as usize) as u32, &words);
            }
            for (o, s) in scales.iter().enumerate() {
                sys.mvus[0].scalers.write(o as u32, *s);
            }
            for (o, b) in biases.iter().enumerate() {
                sys.mvus[0].biases.write(o as u32, *b);
            }
        };
        let mut cyc = System::new(SystemConfig::default());
        load(&mut cyc);
        let mut trb = System::new(SystemConfig { exec: ExecMode::Turbo, ..Default::default() });
        load(&mut trb);

        // --- run on both backends; cycles must match the job formula -------
        let c_cycles = cyc.run_job(0, cfg.clone()).unwrap();
        let t_cycles = trb.run_job(0, cfg.clone()).unwrap();
        assert_eq!(t_cycles, c_cycles, "case {case}: reported job cycles differ");
        assert_eq!(t_cycles, cfg.cycles(), "case {case}: cycles != job formula");
        assert_eq!(
            trb.mvus[0].busy_cycles(),
            cyc.mvus[0].busy_cycles(),
            "case {case}: busy counters differ"
        );
        assert_eq!(trb.mvus[0].jobs_done(), cyc.mvus[0].jobs_done(), "case {case}");

        // --- output regions bit-identical across every MVU -----------------
        let out_words = (outputs / pool_count) * out_bits as u32;
        for m in 0..trb.mvus.len() {
            for addr in OUT_BASE..OUT_BASE + out_words {
                assert_eq!(
                    trb.mvus[m].act.read(addr),
                    cyc.mvus[m].act.read(addr),
                    "case {case}: MVU {m} word {addr} differs across backends"
                );
            }
        }

        // --- third reference: plain-integer golden model -------------------
        let dest_mvu = match dest {
            OutputDest::SelfRam => 0usize,
            OutputDest::Xbar { dest_mask } => dest_mask.trailing_zeros() as usize,
        };
        let relu_init = if relu_en { 0i32 } else { i32::MIN };
        let mut pool_reg = [relu_init; 64];
        let mut filled = 0u32;
        let mut written = 0u32;
        for o in 0..outputs {
            let mut acc = [0i64; 64];
            for t in 0..tiles {
                let x = &a_vals[(o * tiles + t) as usize];
                let wflat: Vec<i32> =
                    w_vals[t as usize].iter().flatten().copied().collect();
                let dot = barvinn::sim::gemv_i32(&wflat, x, 64, 64);
                for (a, &d) in acc.iter_mut().zip(&dot) {
                    *a += d as i64;
                }
            }
            for (l, reg) in pool_reg.iter_mut().enumerate() {
                let mut v = acc[l] as i32;
                if scaler_en {
                    v = ((v as i64) * (scales[o as usize][l] as i64)) as i32;
                }
                if bias_en {
                    v = v.wrapping_add(biases[o as usize][l]);
                }
                if v > *reg {
                    *reg = v;
                }
            }
            filled += 1;
            if filled == pool_count {
                let base = OUT_BASE + written * out_bits as u32;
                for (l, &reg) in pool_reg.iter().enumerate() {
                    let want = barvinn::quant::quantser(reg, quant);
                    let mut got = 0u32;
                    for p in 0..out_bits as u32 {
                        let word = cyc.mvus[dest_mvu].act.read(base + p);
                        got |= (((word >> l) & 1) as u32) << (out_bits as u32 - 1 - p);
                    }
                    assert_eq!(
                        got, want,
                        "case {case}: output {written} lane {l} != golden"
                    );
                }
                pool_reg = [relu_init; 64];
                filled = 0;
                written += 1;
            }
        }
    }
}

/// The trace-memoization acceptance property: a [`JobTrace`] captured
/// once from a job config and replayed over fresh frame data
/// (`run_job_turbo_traced`) is bit-identical to a fresh capture-and-run
/// (`run_job_turbo`) — crossbar writes, output RAM words, busy counters
/// and the reported cycles — across random 1–8-bit precisions
/// (signed/unsigned), tile counts, pooling windows and destinations, with
/// the same trace reused across several reloaded frames.
#[test]
fn memoized_trace_replay_is_bit_identical() {
    use barvinn::exec::{run_job_turbo, run_job_turbo_traced, JobTrace};
    use barvinn::mvu::{AguCfg, JobConfig, Mvu, MvuConfig, OutputDest};
    use barvinn::quant::pack_block;

    const OUT_BASE: u32 = 8000;
    let mut rng = Rng(0x7ACE);
    let cases = if cfg!(debug_assertions) { 24 } else { 80 };
    for case in 0..cases {
        // --- random job geometry (same family as the backend matrix) ------
        let ab = 1 + (rng.next_u64() % 8) as u8;
        let wb = 1 + (rng.next_u64() % 8) as u8;
        let aprec = Precision { bits: ab, signed: ab >= 2 && rng.next_u64() % 2 == 0 };
        let wprec = Precision { bits: wb, signed: wb >= 2 && rng.next_u64() % 2 == 0 };
        let tiles = 1 + (rng.next_u64() % 4) as u32;
        let pool_count = [1u32, 2][(rng.next_u64() % 2) as usize];
        let outputs = pool_count * (1 + (rng.next_u64() % 3) as u32);
        let combos = ab as u32 * wb as u32;
        let out_bits = 1 + (rng.next_u64() % 16) as u8;
        let quant = QuantSerCfg {
            msb_index: (out_bits - 1) + (rng.next_u64() % 8) as u8,
            out_bits,
            saturate: rng.next_u64() % 2 == 0,
        };
        let dest = if rng.next_u64() % 2 == 0 {
            OutputDest::SelfRam
        } else {
            OutputDest::Xbar { dest_mask: 1u8 << (1 + (rng.next_u64() % 7) as u8) }
        };
        let cfg = JobConfig {
            aprec,
            wprec,
            tiles,
            outputs,
            a_agu: AguCfg::from_strides(
                0,
                &[
                    (tiles - 1, ab as i64),
                    (combos - 1, 0),
                    (outputs - 1, (tiles * ab as u32) as i64),
                ],
            ),
            w_agu: AguCfg::from_strides(0, &[(tiles - 1, wb as i64), (combos - 1, 0)]),
            s_agu: AguCfg::from_strides(0, &[(outputs - 1, 1)]),
            b_agu: AguCfg::from_strides(0, &[(outputs - 1, 1)]),
            o_agu: AguCfg::from_strides(
                OUT_BASE,
                &[(outputs / pool_count - 1, out_bits as i64)],
            ),
            scaler_en: rng.next_u64() % 2 == 0,
            bias_en: rng.next_u64() % 2 == 0,
            relu_en: rng.next_u64() % 2 == 0,
            pool_count,
            quant,
            dest,
        };

        // Capture once; the trace must book exactly the job formula.
        let trace = JobTrace::capture(&cfg);
        assert_eq!(trace.cycles(), cfg.cycles(), "case {case}: trace cycles != formula");

        // Reuse the one trace across 3 frames of fresh random data.
        for frame in 0..3 {
            let a_vals: Vec<[i32; 64]> = (0..(outputs * tiles) as usize)
                .map(|_| {
                    std::array::from_fn(|_| rng.range_i32(aprec.min_value(), aprec.max_value()))
                })
                .collect();
            let w_vals: Vec<[[i32; 64]; 64]> = (0..tiles as usize)
                .map(|_| {
                    std::array::from_fn(|_| {
                        std::array::from_fn(|_| {
                            rng.range_i32(wprec.min_value(), wprec.max_value())
                        })
                    })
                })
                .collect();
            let scales: Vec<[u16; 64]> = (0..outputs as usize)
                .map(|_| std::array::from_fn(|_| rng.range_i32(1, 6) as u16))
                .collect();
            let biases: Vec<[i32; 64]> = (0..outputs as usize)
                .map(|_| std::array::from_fn(|_| rng.range_i32(-500, 500)))
                .collect();
            let load = |mvu: &mut Mvu| {
                for (b, vals) in a_vals.iter().enumerate() {
                    mvu.act.load((b * ab as usize) as u32, &pack_block(vals, aprec));
                }
                for (t, tile) in w_vals.iter().enumerate() {
                    let rows: Vec<Vec<u64>> = tile.iter().map(|r| pack_block(r, wprec)).collect();
                    let words: Vec<[u64; 64]> = (0..wb as usize)
                        .map(|p| std::array::from_fn(|r| rows[r][p]))
                        .collect();
                    mvu.weights.load((t * wb as usize) as u32, &words);
                }
                for (o, s) in scales.iter().enumerate() {
                    mvu.scalers.write(o as u32, *s);
                }
                for (o, b) in biases.iter().enumerate() {
                    mvu.biases.write(o as u32, *b);
                }
            };

            let mut fresh = Mvu::new(0, MvuConfig::default());
            load(&mut fresh);
            let mut replayed = Mvu::new(0, MvuConfig::default());
            load(&mut replayed);

            let (fresh_writes, fresh_cycles) = run_job_turbo(&mut fresh, &cfg).unwrap();
            let (trace_writes, trace_cycles) =
                run_job_turbo_traced(&mut replayed, &cfg, &trace).unwrap();
            assert_eq!(trace_cycles, fresh_cycles, "case {case} frame {frame}: cycles");
            assert_eq!(trace_writes, fresh_writes, "case {case} frame {frame}: xbar writes");
            assert_eq!(
                replayed.busy_cycles(),
                fresh.busy_cycles(),
                "case {case} frame {frame}: busy counters"
            );
            let out_words = (outputs / pool_count) * out_bits as u32;
            for addr in OUT_BASE..OUT_BASE + out_words {
                assert_eq!(
                    replayed.act.read(addr),
                    fresh.act.read(addr),
                    "case {case} frame {frame}: word {addr} differs"
                );
            }
        }
    }
}

/// Random linear 64-channel conv chain at constant spatial size `h` (3×3,
/// stride 1, pad 1): per-layer random 1–8-bit precisions chaining through
/// `oprec → next aprec`, random ReLU, and a quant window wide enough that
/// accumulators never saturate surprisingly. Shared by the multi-pass and
/// the streamed-execution property tests.
fn random_chain_model(rng: &mut Rng, case: u64, depth: usize, h: usize) -> barvinn::model::Model {
    let mut a_bits = 1 + (rng.next_u64() % 8) as u8;
    let mut layers = Vec::with_capacity(depth);
    for i in 0..depth {
        let w_bits = 1 + (rng.next_u64() % 8) as u8;
        let o_bits = 1 + (rng.next_u64() % 8) as u8;
        let aprec = Precision::u(a_bits);
        let wprec = Precision::s(w_bits);
        let max_acc = (64 * 9) as i64
            * aprec.max_value() as i64
            * wprec.min_value().unsigned_abs() as i64;
        let msb = 63 - ((max_acc * 4) as u64).leading_zeros() as u8;
        layers.push(ConvLayer {
            name: format!("c{case}l{i}"),
            ci: 64,
            co: 64,
            fh: 3,
            fw: 3,
            stride: 1,
            pad: 1,
            in_h: h,
            in_w: h,
            aprec,
            wprec,
            oprec: Precision::u(o_bits),
            relu: rng.next_u64() % 2 == 0,
            weights: (0..64 * 64 * 9)
                .map(|_| rng.range_i32(wprec.min_value(), wprec.max_value()))
                .collect(),
            quant: QuantSpec {
                scale: (0..64).map(|_| rng.range_i32(1, 4) as u16).collect(),
                bias: (0..64).map(|_| rng.range_i32(-64, 64)).collect(),
                quant_msb: msb,
            },
        });
        a_bits = o_bits;
    }
    let model = barvinn::model::Model {
        name: format!("prop-depth-{depth}"),
        layers,
        host_prologue: None,
        host_epilogue: None,
    };
    model.validate().unwrap_or_else(|e| panic!("case {case}: {e}"));
    model
}

/// The multi-pass acceptance property: random-depth models (1–20 layers,
/// random 1–8-bit precisions per layer) served through the session's
/// depth-resolving `Auto` mode agree bit-for-bit with `sim::golden` and
/// across both execution backends — outputs, per-entry cycle accounting
/// and totals included. Depths above 8 exercise multi-pass scheduling
/// (weight rotation + activation carry between passes); 1 resolves to
/// distributed, 2–8 to single-pass pipelined.
#[test]
fn random_depth_models_agree_with_golden_across_backends() {
    use barvinn::exec::ExecMode;
    use barvinn::session::{ExecutionMode, SessionBuilder};

    let mut rng = Rng(0xDEE9);
    let (cases, h) = if cfg!(debug_assertions) { (2, 4usize) } else { (6, 6usize) };
    for case in 0..cases {
        let depth = 1 + (rng.next_u64() % 20) as usize;
        let model = random_chain_model(&mut rng, case, depth, h);

        let l0 = &model.layers[0];
        let input = Tensor3::from_fn(l0.ci, l0.in_h, l0.in_w, |_, _, _| {
            rng.range_i32(0, l0.aprec.max_value())
        });
        // Golden integer reference.
        let want = model.golden_forward(&input);
        let analytic: u64 = model
            .layers
            .iter()
            .map(|l| layer_cycles(l, EdgePolicy::PadInRam))
            .sum();

        let mut runs = Vec::new();
        for exec in [ExecMode::Turbo, ExecMode::CycleAccurate] {
            let mut session = SessionBuilder::new(model.clone())
                .mode(ExecutionMode::Auto)
                .edge_policy(EdgePolicy::PadInRam)
                .exec_mode(exec)
                .build()
                .unwrap_or_else(|e| panic!("case {case} depth {depth} ({exec:?}): {e}"));
            if depth > 8 {
                assert_eq!(session.execution_mode(), ExecutionMode::MultiPass);
                assert_eq!(session.n_passes(), depth.div_ceil(8), "case {case}");
            }
            let out = session
                .run(&input)
                .unwrap_or_else(|e| panic!("case {case} depth {depth} ({exec:?}): {e}"));
            assert_eq!(
                out.output, want,
                "case {case} depth {depth} ({exec:?}): output != golden"
            );
            assert_eq!(
                out.total_mvu_cycles, analytic,
                "case {case} depth {depth} ({exec:?}): cycle accounting"
            );
            runs.push(out);
        }
        // Backends agree bit-for-bit, per-entry cycles included.
        assert_eq!(runs[0].output, runs[1].output, "case {case}");
        assert_eq!(runs[0].mvu_cycles, runs[1].mvu_cycles, "case {case}");
    }
}

/// The streamed-execution acceptance property: a batch run with up to 8
/// frames in flight across the MVU stages (`run_stream`, double-buffered
/// activation regions) is **bit-identical** to serial `run` — per-frame
/// outputs *and* per-layer (Table-3/Table-5-style) cycle counts — across
/// random 1–8-bit per-layer precisions, depths 2–8 and both execution
/// backends; and the batch's modelled pipeline wall never exceeds the
/// serial cost.
#[test]
fn streamed_and_serial_execution_agree_across_precisions_and_depths() {
    use barvinn::exec::ExecMode;
    use barvinn::session::SessionBuilder;

    let mut rng = Rng(0x57AE);
    let (cases, h, frames) =
        if cfg!(debug_assertions) { (3u64, 4usize, 3usize) } else { (10, 6, 4) };
    for case in 0..cases {
        let depth = 2 + (rng.next_u64() % 7) as usize; // 2..=8: one pipelined pass
        let model = random_chain_model(&mut rng, 1000 + case, depth, h);
        let l0 = &model.layers[0];
        let inputs: Vec<Tensor3> = (0..frames)
            .map(|_| {
                Tensor3::from_fn(l0.ci, l0.in_h, l0.in_w, |_, _, _| {
                    rng.range_i32(0, l0.aprec.max_value())
                })
            })
            .collect();
        let per_layer: Vec<u64> = model
            .layers
            .iter()
            .map(|l| layer_cycles(l, EdgePolicy::PadInRam))
            .collect();

        for exec in [ExecMode::Turbo, ExecMode::CycleAccurate] {
            let mut serial = SessionBuilder::new(model.clone())
                .edge_policy(EdgePolicy::PadInRam)
                .exec_mode(exec)
                .build()
                .unwrap_or_else(|e| panic!("case {case} ({exec:?}): {e}"));
            let mut streamed = SessionBuilder::new(model.clone())
                .edge_policy(EdgePolicy::PadInRam)
                .exec_mode(exec)
                .build()
                .unwrap();
            let batch = streamed
                .run_stream(&inputs)
                .unwrap_or_else(|e| panic!("case {case} depth {depth} ({exec:?}): {e}"));
            assert_eq!(batch.outputs.len(), frames, "case {case} ({exec:?})");
            for (f, input) in inputs.iter().enumerate() {
                let want = serial.run(input).unwrap();
                let got = &batch.outputs[f];
                assert_eq!(
                    got.output, want.output,
                    "case {case} depth {depth} frame {f} ({exec:?}): streamed != serial"
                );
                assert_eq!(
                    got.mvu_cycles, want.mvu_cycles,
                    "case {case} frame {f} ({exec:?}): per-layer cycles"
                );
                // Per-layer counts are the analytic Table-3-style formula.
                for (k, &c) in per_layer.iter().enumerate() {
                    assert_eq!(
                        got.mvu_cycles[k], c,
                        "case {case} frame {f} layer {k} ({exec:?})"
                    );
                }
                // Third reference: the plain-integer golden model.
                assert_eq!(got.output, model.golden_forward(input), "case {case} frame {f}");
            }
            let s = &batch.stream;
            assert_eq!(s.stages, depth, "case {case}");
            assert_eq!(s.serial_cycles, per_layer.iter().sum::<u64>() * frames as u64);
            assert!(
                s.pipeline_cycles <= s.serial_cycles,
                "case {case} ({exec:?}): streaming must never cost more than serial"
            );
            assert!(
                s.pipeline_cycles >= s.bottleneck_cycles * frames as u64,
                "case {case} ({exec:?}): cannot beat one frame per bottleneck lap"
            );
        }
    }
}

/// The lap-parallelism acceptance property: a streamed turbo batch run
/// with N lap-worker threads is bit-identical to the single-threaded run —
/// per-frame outputs, per-layer cycle counts and the whole pipeline book —
/// across random per-layer precisions and depths. Threads are a wall-clock
/// knob only; the gather-then-apply crossbar ordering keeps results
/// independent of worker interleaving.
#[test]
fn threaded_streamed_turbo_is_bit_identical_to_single_threaded() {
    use barvinn::exec::ExecMode;
    use barvinn::session::SessionBuilder;

    let mut rng = Rng(0x7B9D);
    let (cases, h, frames) = if cfg!(debug_assertions) { (2u64, 4usize, 3usize) } else { (5, 6, 4) };
    for case in 0..cases {
        let depth = 2 + (rng.next_u64() % 7) as usize;
        let model = random_chain_model(&mut rng, 2000 + case, depth, h);
        let l0 = &model.layers[0];
        let inputs: Vec<Tensor3> = (0..frames)
            .map(|_| {
                Tensor3::from_fn(l0.ci, l0.in_h, l0.in_w, |_, _, _| {
                    rng.range_i32(0, l0.aprec.max_value())
                })
            })
            .collect();

        let mut run_at = |threads: usize| {
            let mut s = SessionBuilder::new(model.clone())
                .edge_policy(EdgePolicy::PadInRam)
                .exec_mode(ExecMode::Turbo)
                .threads(threads)
                .build()
                .unwrap_or_else(|e| panic!("case {case} threads {threads}: {e}"));
            s.run_stream(&inputs)
                .unwrap_or_else(|e| panic!("case {case} threads {threads}: {e}"))
        };
        let base = run_at(1);
        for threads in [2, 4, 8] {
            let got = run_at(threads);
            assert_eq!(
                got.stream.pipeline_cycles, base.stream.pipeline_cycles,
                "case {case} threads {threads}: pipeline books diverged"
            );
            for (f, (x, y)) in base.outputs.iter().zip(&got.outputs).enumerate() {
                assert_eq!(
                    y.output, x.output,
                    "case {case} threads {threads} frame {f}: outputs diverged"
                );
                assert_eq!(
                    y.mvu_cycles, x.mvu_cycles,
                    "case {case} threads {threads} frame {f}: per-layer cycles diverged"
                );
            }
        }
    }
}

/// The streamed-engine equivalence property: the generated multi-frame
/// Pito program executed on the modelled CPU (`StreamDriver::Program` —
/// per-row flag-wait/flag-bump sync and odd/even parity selection encoded
/// in the instruction stream) is bit-identical to the host-driven
/// `StreamSchedule` lap replay (`StreamDriver::HostLaps`) on the same
/// cycle-accurate backend, across random 2–8-deep chains of random
/// 1–8-bit per-layer precisions: per-frame outputs, per-layer cycle books
/// and every stream-accounting field except the measured wall (the
/// program-driven wall additionally books the CPU's flag-spin and launch
/// overhead).
#[test]
fn streamed_program_and_host_lap_replay_are_bit_identical() {
    use barvinn::exec::ExecMode;
    use barvinn::session::{SessionBuilder, StreamDriver};

    let mut rng = Rng(0x9B0C);
    let (default_cases, h, frames) =
        if cfg!(debug_assertions) { (2u64, 4usize, 3usize) } else { (6, 6, 4) };
    let cases = proptest_cases(default_cases);
    for case in 0..cases {
        let depth = 2 + (rng.next_u64() % 7) as usize; // 2..=8: one pipelined pass
        let model = random_chain_model(&mut rng, 4000 + case, depth, h);
        let l0 = &model.layers[0];
        let inputs: Vec<Tensor3> = (0..frames)
            .map(|_| {
                Tensor3::from_fn(l0.ci, l0.in_h, l0.in_w, |_, _, _| {
                    rng.range_i32(0, l0.aprec.max_value())
                })
            })
            .collect();

        let mut run_with = |driver: StreamDriver| {
            let mut s = SessionBuilder::new(model.clone())
                .edge_policy(EdgePolicy::PadInRam)
                .exec_mode(ExecMode::CycleAccurate)
                .stream_driver(driver)
                .build()
                .unwrap_or_else(|e| panic!("case {case} depth {depth} ({driver:?}): {e}"));
            s.run_stream(&inputs)
                .unwrap_or_else(|e| panic!("case {case} depth {depth} ({driver:?}): {e}"))
        };
        let a = run_with(StreamDriver::Program);
        let b = run_with(StreamDriver::HostLaps);

        assert_eq!(a.outputs.len(), b.outputs.len(), "case {case}");
        for (f, (x, y)) in a.outputs.iter().zip(&b.outputs).enumerate() {
            assert_eq!(x.output, y.output, "case {case} frame {f}: outputs diverged");
            assert_eq!(
                x.mvu_cycles, y.mvu_cycles,
                "case {case} frame {f}: per-layer cycle books diverged"
            );
            assert_eq!(
                x.output,
                model.golden_forward(&inputs[f]),
                "case {case} frame {f}: != golden"
            );
        }
        let (s, t) = (a.stream, b.stream);
        assert_eq!(s.frames, t.frames, "case {case}");
        assert_eq!(s.stages, t.stages, "case {case}");
        assert_eq!(s.fill_cycles, t.fill_cycles, "case {case}");
        assert_eq!(s.steady_cycles, t.steady_cycles, "case {case}");
        assert_eq!(s.drain_cycles, t.drain_cycles, "case {case}");
        assert_eq!(s.pipeline_cycles, t.pipeline_cycles, "case {case}");
        assert_eq!(s.bottleneck_cycles, t.bottleneck_cycles, "case {case}");
        assert_eq!(s.serial_cycles, t.serial_cycles, "case {case}");
        assert!(
            s.measured_cycles >= s.bottleneck_cycles * frames as u64,
            "case {case}: program-driven wall beat one frame per bottleneck lap"
        );
    }
}

/// The continuous-admission acceptance property: frames joining a
/// *running* pipeline at random arrival laps (`run_continuous` over a
/// [`StreamFeed`], and the serving-path `open_pipeline`/`run_batch`
/// chunked admission) are **bit-identical** to fresh serial `run` and to
/// closed `run_batch` — per-frame outputs, per-layer cycle books and
/// (continuous vs closed) the final activation-RAM state — across random
/// 2–8-deep chains of random 1–8-bit per-layer precisions, random arrival
/// interleavings, both execution backends and both stream drivers.
/// Admission timing moves only the lap accounting, which must match the
/// open [`StreamSchedule`] for the trace exactly, and its occupancy must
/// dominate deferring the same frames to a closed batch at the last
/// arrival.
#[test]
fn continuous_admission_is_bit_identical_to_closed_batches() {
    use barvinn::exec::{ExecMode, StreamSchedule};
    use barvinn::session::{SessionBuilder, StreamDriver, StreamFeed};

    let mut rng = Rng(0xAD317);
    let (default_cases, h, frames) =
        if cfg!(debug_assertions) { (2u64, 4usize, 4usize) } else { (6, 6, 6) };
    let cases = proptest_cases(default_cases);
    for case in 0..cases {
        let depth = 2 + (rng.next_u64() % 7) as usize; // 2..=8: one pipelined pass
        let model = random_chain_model(&mut rng, 5000 + case, depth, h);
        let l0 = &model.layers[0];
        let inputs: Vec<Tensor3> = (0..frames)
            .map(|_| {
                Tensor3::from_fn(l0.ci, l0.in_h, l0.in_w, |_, _, _| {
                    rng.range_i32(0, l0.aprec.max_value())
                })
            })
            .collect();
        // Random arrival interleaving: a monotone lap trace with 0..=3
        // idle laps between consecutive frames (gaps beyond the pipeline
        // depth become modelled bubbles).
        let mut arrivals = Vec::with_capacity(frames);
        let mut lap = 0usize;
        for _ in 0..frames {
            lap += (rng.next_u64() % 4) as usize;
            arrivals.push(lap);
        }
        let stage_cycles: Vec<u64> =
            model.layers.iter().map(|l| layer_cycles(l, EdgePolicy::PadInRam)).collect();
        // The open schedule this trace induces, and the deferred
        // alternative (wait for every frame, then run a closed batch).
        let mut open = StreamSchedule::open(stage_cycles.clone());
        for &a in &arrivals {
            open.admit(a);
        }
        let open_cycles = open.cycles();
        let mut deferred = StreamSchedule::open(stage_cycles.clone());
        for _ in 0..frames {
            deferred.admit(*arrivals.last().unwrap());
        }
        let closed_total = StreamSchedule::new(stage_cycles.clone(), frames).cycles().total();

        // Program runs only on the cycle-accurate backend; host-lap replay
        // runs on both.
        let combos = [
            (ExecMode::Turbo, StreamDriver::HostLaps),
            (ExecMode::CycleAccurate, StreamDriver::HostLaps),
            (ExecMode::CycleAccurate, StreamDriver::Program),
        ];
        for (exec, driver) in combos {
            let build = || {
                SessionBuilder::new(model.clone())
                    .edge_policy(EdgePolicy::PadInRam)
                    .exec_mode(exec)
                    .stream_driver(driver)
                    .build()
                    .unwrap_or_else(|e| panic!("case {case} ({exec:?}/{driver:?}): {e}"))
            };
            let tag = format!("case {case} depth {depth} ({exec:?}/{driver:?})");

            // Reference 1: fresh serial replay, one frame at a time.
            let mut serial = build();
            let want: Vec<_> = inputs
                .iter()
                .map(|i| serial.run(i).unwrap_or_else(|e| panic!("{tag}: serial: {e}")))
                .collect();

            // Reference 2: the closed batch (all frames waiting at lap 0).
            let mut closed = build();
            let closed_out =
                closed.run_batch(&inputs).unwrap_or_else(|e| panic!("{tag}: closed: {e}"));
            let closed_digest = closed.activation_ram_digest();
            assert_eq!(closed_out.stream.pipeline_cycles, closed_total, "{tag}: closed wall");

            // Continuous admission of the same frames at their arrivals.
            let mut feed = StreamFeed::new();
            for (input, &a) in inputs.iter().zip(&arrivals) {
                feed.push_at(input.clone(), a);
            }
            let mut cont = build();
            let cont_out =
                cont.run_continuous(&feed).unwrap_or_else(|e| panic!("{tag}: continuous: {e}"));
            assert_eq!(cont_out.outputs.len(), frames, "{tag}");

            for f in 0..frames {
                let golden = model.golden_forward(&inputs[f]);
                assert_eq!(cont_out.outputs[f].output, want[f].output, "{tag} frame {f}");
                assert_eq!(
                    cont_out.outputs[f].mvu_cycles, want[f].mvu_cycles,
                    "{tag} frame {f}: per-layer cycle books"
                );
                assert_eq!(closed_out.outputs[f].output, want[f].output, "{tag} frame {f}");
                assert_eq!(
                    closed_out.outputs[f].mvu_cycles, want[f].mvu_cycles,
                    "{tag} frame {f}: closed cycle books"
                );
                assert_eq!(cont_out.outputs[f].output, golden, "{tag} frame {f}: != golden");
            }
            // Admission timing must not leak into the machine: the RAMs end
            // bit-identical to the closed batch of the same frames.
            assert_eq!(
                cont.activation_ram_digest(),
                closed_digest,
                "{tag}: continuous left different activation-RAM state than closed"
            );

            // The lap accounting is exactly the open schedule of the trace.
            let s = &cont_out.stream;
            assert_eq!(s.fill_cycles, open_cycles.fill, "{tag}: fill");
            assert_eq!(s.steady_cycles, open_cycles.steady, "{tag}: steady");
            assert_eq!(s.drain_cycles, open_cycles.drain, "{tag}: drain");
            assert_eq!(s.pipeline_cycles, open_cycles.total(), "{tag}: wall");
            assert_eq!(
                s.serial_cycles,
                stage_cycles.iter().sum::<u64>() * frames as u64,
                "{tag}: serial book"
            );
            // Occupancy dominance: admitting at arrival never loses to
            // deferring the whole trace into one closed batch.
            assert!(
                s.pipeline_cycles <= deferred.cycles().total(),
                "{tag}: open wall {} must not exceed deferred-closed wall {}",
                s.pipeline_cycles,
                deferred.cycles().total()
            );
            assert!(
                s.occupancy() + 1e-12
                    >= s.serial_cycles as f64
                        / (deferred.cycles().total() * depth as u64) as f64,
                "{tag}: occupancy must dominate the deferred closed batch"
            );

            // Serving-path chunked admission: random flushes into one open
            // pipeline partition the dense schedule — outputs identical,
            // fill paid once, drain deferred to close.
            let mut chunked = build();
            assert!(chunked.open_pipeline(), "{tag}: pipelined sessions must open");
            let mut got = Vec::new();
            let mut booked = 0u64;
            let mut per_chunk_closed = 0u64;
            let mut i = 0usize;
            while i < frames {
                let n = (1 + (rng.next_u64() % 3) as usize).min(frames - i);
                let out = chunked
                    .run_batch(&inputs[i..i + n])
                    .unwrap_or_else(|e| panic!("{tag}: chunk at {i}: {e}"));
                booked += out.stream.pipeline_cycles;
                per_chunk_closed += StreamSchedule::new(stage_cycles.clone(), n).cycles().total();
                got.extend(out.outputs);
                i += n;
            }
            let tail = chunked.close_pipeline();
            assert_eq!(tail.frames, 0, "{tag}: the tail reports no frames");
            booked += tail.pipeline_cycles;
            assert_eq!(
                booked, closed_total,
                "{tag}: chunk windows + drain tail must partition the dense schedule"
            );
            assert!(
                booked <= per_chunk_closed,
                "{tag}: open admission ({booked}) must never book more than \
                 per-flush closed batches ({per_chunk_closed})"
            );
            assert_eq!(got.len(), frames, "{tag}");
            for (f, out) in got.iter().enumerate() {
                assert_eq!(out.output, want[f].output, "{tag} chunked frame {f}");
                assert_eq!(
                    out.mvu_cycles, want[f].mvu_cycles,
                    "{tag} chunked frame {f}: cycle books"
                );
            }
        }
    }
}

/// The checker-vs-runtime agreement property: every random chain model the
/// static verifier admits (at Full level, symbolic bounds cross-checked
/// against captured traces) runs clean end-to-end against the golden
/// reference — and seeded mutations of the same compiled plans are
/// rejected statically with the matching stable code. The verifier is only
/// trustworthy as an admission gate if it neither under- nor over-rejects
/// on plans the compiler actually emits.
#[test]
fn verifier_agrees_with_runtime_on_random_chains() {
    use barvinn::analysis::{verify_pipelined, DiagCode, VerifyLevel};
    use barvinn::codegen::compile_pipelined;
    use barvinn::exec::ExecMode;
    use barvinn::mvu::MvuConfig;
    use barvinn::session::SessionBuilder;

    let mut rng = Rng(0x5EED);
    let (cases, h) = if cfg!(debug_assertions) { (2u64, 4usize) } else { (6, 6) };
    let cfg = MvuConfig::default();
    for case in 0..cases {
        let depth = 2 + (rng.next_u64() % 7) as usize; // 2..=8: pipelined
        let model = random_chain_model(&mut rng, 3000 + case, depth, h);

        // Admitted statically…
        let c = compile_pipelined(&model, EdgePolicy::PadInRam).unwrap();
        let report = verify_pipelined(&c, &model, &cfg, VerifyLevel::Full);
        assert!(
            report.is_clean(),
            "case {case} depth {depth}: verifier over-rejects a sound plan: {:?}",
            report.diagnostics
        );

        // …runs clean on both backends, through the default-on session gate.
        let l0 = &model.layers[0];
        let input = Tensor3::from_fn(l0.ci, l0.in_h, l0.in_w, |_, _, _| {
            rng.range_i32(0, l0.aprec.max_value())
        });
        let want = model.golden_forward(&input);
        for exec in [ExecMode::Turbo, ExecMode::CycleAccurate] {
            let mut session = SessionBuilder::new(model.clone())
                .edge_policy(EdgePolicy::PadInRam)
                .exec_mode(exec)
                .build()
                .unwrap_or_else(|e| panic!("case {case} ({exec:?}): gate rejected: {e}"));
            let out = session.run(&input).unwrap();
            assert_eq!(out.output, want, "case {case} depth {depth} ({exec:?})");
        }

        // Seeded mutations of the admitted plan are each caught with the
        // right code (fresh compile per mutation — plans are not Clone).
        let mutations: [(&str, DiagCode, fn(&mut barvinn::codegen::CompiledModel)); 4] = [
            ("oob address", DiagCode::AddrOob, |c| {
                c.plans[0].jobs[0].a_agu.base = 1 << 20;
            }),
            ("buffer-shifted read", DiagCode::DefUse, |c| {
                let shift = c.plans[0].in_layout.size_words();
                for j in &mut c.plans[0].jobs {
                    j.a_agu.base += shift;
                }
            }),
            ("parity flip", DiagCode::StreamParity, |c| {
                c.stream_plans[0] = c.plans[0].clone();
            }),
            ("tile inflation", DiagCode::CycleBudget, |c| {
                c.plans[0].jobs[0].tiles += 1;
            }),
        ];
        for (what, code, mutate) in mutations {
            let mut bad = compile_pipelined(&model, EdgePolicy::PadInRam).unwrap();
            mutate(&mut bad);
            let r = verify_pipelined(&bad, &model, &cfg, VerifyLevel::Quick);
            assert!(
                r.has(code),
                "case {case} depth {depth}: {what} must be rejected as {code}, got {:?}",
                r.diagnostics
            );
        }
    }
}

/// Assembler fuzz: random valid programs assemble, disassemble and
/// re-assemble to identical words.
#[test]
fn assembler_fuzz_roundtrip() {
    use barvinn::pito::{assemble, disassemble};
    let mut rng = Rng(0xA53);
    for _ in 0..2000 {
        let w = rng.next_u64() as u32;
        if barvinn::pito::decode(w).is_ok() {
            let text = disassemble(barvinn::pito::encode(barvinn::pito::decode(w).unwrap()));
            let re = assemble(&text).unwrap_or_else(|e| panic!("'{text}': {e}"));
            assert_eq!(re.len(), 1);
            assert_eq!(
                barvinn::pito::decode(re[0]).unwrap(),
                barvinn::pito::decode(w).unwrap(),
                "via '{text}'"
            );
        }
    }
}

/// Whole-program round-trip idempotence: for random valid instruction
/// *sequences* `p`, `assemble(disasm(assemble_canonical(p)))` is the
/// identity — the textual form is a fixpoint, so the disassembler is a
/// faithful inverse at program granularity (label-free addressing,
/// sign-extended immediates, CSR names) and not just per word.
#[test]
fn program_disassembly_roundtrip_is_idempotent() {
    use barvinn::pito::{assemble, decode, disassemble, encode};
    let mut rng = Rng(0x90B1);
    for case in 0..200 {
        // Random valid sequence: sample raw words, keep the decodable ones.
        let len = 1 + (rng.next_u64() % 64) as usize;
        let mut canonical = Vec::with_capacity(len);
        while canonical.len() < len {
            if let Ok(instr) = decode(rng.next_u64() as u32) {
                canonical.push(encode(instr));
            }
        }
        let text: String =
            canonical.iter().map(|&w| disassemble(w)).collect::<Vec<_>>().join("\n");
        let once = assemble(&text).unwrap_or_else(|e| panic!("case {case}: '{text}': {e}"));
        assert_eq!(once, canonical, "case {case}: reassembly must reproduce the words");
        let text2: String =
            once.iter().map(|&w| disassemble(w)).collect::<Vec<_>>().join("\n");
        let twice = assemble(&text2).unwrap_or_else(|e| panic!("case {case}: '{text2}': {e}"));
        assert_eq!(twice, once, "case {case}: the round trip must be a fixpoint");
    }
}
