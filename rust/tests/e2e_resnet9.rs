//! Integration: the full quantized ResNet9 through the pito-driven 8-MVU
//! pipeline at real 32×32 scale, driven by the unified
//! [`barvinn::session::InferenceSession`] API and verified bit-exactly
//! against the Rust golden model, plus Table-3 cycle accounting and the
//! warm-session reuse guarantee.
//!
//! Heavy paths are release-only (`make test` runs `cargo test --release`);
//! under debug they downscale to keep `cargo test` responsive.

use barvinn::accel::{System, SystemConfig, SystemExit};
use barvinn::codegen::{compile_pipelined, CompileError, EdgePolicy};
use barvinn::exec::ExecMode;
use barvinn::model::zoo::{resnet9_cifar10, Rng};
use barvinn::model::Model;
use barvinn::session::{SessionBuilder, SessionError};
use barvinn::sim::Tensor3;

fn golden_forward(model: &Model, input: &Tensor3) -> Tensor3 {
    model.golden_forward(input)
}

fn model_under_test() -> Model {
    let mut m = resnet9_cifar10(2, 2);
    if cfg!(debug_assertions) {
        // Downscale spatially (keeps all 8 layers + channel widths).
        let mut h = 16;
        for l in &mut m.layers {
            l.in_h = h;
            l.in_w = h;
            if l.stride == 2 {
                h /= 2;
            }
        }
    }
    m.validate().unwrap();
    m
}

fn random_input(m: &Model, seed: u64) -> Tensor3 {
    let l0 = &m.layers[0];
    let mut rng = Rng(seed);
    Tensor3::from_fn(l0.ci, l0.in_h, l0.in_w, |_, _, _| rng.range_i32(0, 3))
}

#[test]
fn pipelined_full_resnet9_bit_exact() {
    // Default-built session: exercises the turbo backend end-to-end.
    let m = model_under_test();
    let mut session = SessionBuilder::new(m.clone())
        .edge_policy(EdgePolicy::PadInRam)
        .build()
        .unwrap();
    assert_eq!(session.exec_mode(), ExecMode::Turbo, "run() defaults to turbo");
    let input = random_input(&m, 2026);
    let out = session.run(&input).unwrap();
    assert_eq!(out.exec, ExecMode::Turbo);
    assert_eq!(out.output, golden_forward(&m, &input), "accelerator != golden");
    assert_eq!(
        out.total_mvu_cycles,
        compile_pipelined(&m, EdgePolicy::PadInRam).unwrap().total_analytic_cycles()
    );
}

/// The backend-equivalence acceptance test at ResNet-9 scale: turbo and
/// cycle-accurate sessions agree bit-for-bit on the output tensor and on
/// every per-MVU (= per-layer) reported job cycle count, with the golden
/// integer model as the third reference.
#[test]
fn resnet9_turbo_matches_cycle_accurate() {
    let m = model_under_test();
    let mut turbo = SessionBuilder::new(m.clone())
        .edge_policy(EdgePolicy::PadInRam)
        .exec_mode(ExecMode::Turbo)
        .build()
        .unwrap();
    let mut cycle = SessionBuilder::new(m.clone())
        .edge_policy(EdgePolicy::PadInRam)
        .exec_mode(ExecMode::CycleAccurate)
        .build()
        .unwrap();
    for seed in [21u64, 22] {
        let input = random_input(&m, seed);
        let t = turbo.run(&input).unwrap();
        let c = cycle.run(&input).unwrap();
        assert_eq!(t.output, c.output, "seed {seed}: outputs differ across backends");
        assert_eq!(t.output, golden_forward(&m, &input), "seed {seed}: != golden");
        assert_eq!(t.mvu_cycles, c.mvu_cycles, "seed {seed}: per-layer job cycles differ");
        assert_eq!(t.total_mvu_cycles, c.total_mvu_cycles, "seed {seed}");
    }
}

/// The warm-session guarantee: one session serving ≥3 images is bit-exact
/// with a freshly built system (full rebuild + weight reload) per image.
#[test]
fn session_reuse_matches_fresh_system_across_images() {
    // Pinned to the cycle-accurate backend: this test also asserts the
    // global system clock matches a fresh per-image system, which only the
    // timing backend reports.
    let m = model_under_test();
    let mut session = SessionBuilder::new(m.clone())
        .edge_policy(EdgePolicy::PadInRam)
        .exec_mode(ExecMode::CycleAccurate)
        .build()
        .unwrap();
    let compiled = compile_pipelined(&m, EdgePolicy::PadInRam).unwrap();
    for seed in [7u64, 8, 9] {
        let input = random_input(&m, seed);
        let warm = session.run(&input).unwrap();

        let mut fresh = System::new(SystemConfig::default());
        compiled.load_into(&mut fresh, &input);
        assert_eq!(fresh.run(), SystemExit::AllExited, "{:?}", fresh.launch_errors());
        let cold = compiled.read_output(&fresh, m.layers.last().unwrap().co);

        assert_eq!(warm.output, cold, "seed {seed}: warm session != fresh system");
        assert_eq!(warm.output, golden_forward(&m, &input), "seed {seed}: != golden");
        assert_eq!(
            warm.total_mvu_cycles,
            fresh.total_mvu_busy_cycles(),
            "seed {seed}: cycle accounting drifted across reuse"
        );
        assert_eq!(warm.system_cycles, fresh.cycles(), "seed {seed}: system clock drifted");
    }
    assert_eq!(session.metrics().images, 3);
}

/// Typed errors surface through the integration-level API: a tiny fuel
/// limit exhausts, a malformed model fails compilation.
#[test]
fn session_errors_surface_typed() {
    let m = model_under_test();
    let mut starved = SessionBuilder::new(m.clone()).fuel(200).build().unwrap();
    match starved.run(&random_input(&m, 1)) {
        Err(SessionError::FuelExhausted { fuel: 200 }) => {}
        other => panic!("expected FuelExhausted, got {:?}", other.map(|o| o.image_index)),
    }

    let mut bad = model_under_test();
    bad.layers[2].weights.pop(); // weight length mismatch
    match SessionBuilder::new(bad).build() {
        Err(SessionError::Compile(CompileError::InvalidModel(_))) => {}
        other => panic!("expected Compile(InvalidModel), got {:?}", other.err()),
    }
}

#[test]
fn table3_cycles_full_scale() {
    // Analytic accounting at real scale is cheap in any build mode.
    let m = resnet9_cifar10(2, 2);
    let expected = [34560u64, 34560, 17280, 32256, 16128, 27648, 13824, 18432];
    for (l, &want) in m.layers.iter().zip(&expected) {
        assert_eq!(
            barvinn::codegen::layer_cycles(l, EdgePolicy::SkipEdges),
            want,
            "{}",
            l.name
        );
    }
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only (make test): full 32x32 measured run")]
fn table3_cycles_measured_full_scale() {
    let m = resnet9_cifar10(2, 2);
    let mut session = SessionBuilder::new(m)
        .edge_policy(EdgePolicy::SkipEdges)
        .build()
        .unwrap();
    let input = Tensor3::from_fn(64, 32, 32, {
        let mut rng = Rng(7);
        move |_, _, _| rng.range_i32(0, 3)
    });
    let out = session.run(&input).unwrap();
    let expected = [34560u64, 34560, 17280, 32256, 16128, 27648, 13824, 18432];
    for (h, &want) in expected.iter().enumerate() {
        assert_eq!(out.mvu_cycles[h], want, "layer {h}");
    }
    assert_eq!(out.total_mvu_cycles, 194_688, "Table 3 total");
}

#[test]
fn mixed_precision_pipeline() {
    // 1-bit weights / 2-bit activations end-to-end (precision is per-MVU
    // runtime state), served through the same session API — runtime
    // precision switching costs one build.
    let shrink = |mut m: Model| {
        let mut h = 8;
        for l in &mut m.layers {
            l.in_h = h;
            l.in_w = h;
            if l.stride == 2 {
                h /= 2;
            }
        }
        m.layers.truncate(5);
        m.validate().unwrap();
        m
    };
    let m = shrink(resnet9_cifar10(2, 1));
    let mut session = SessionBuilder::new(m.clone()).build().unwrap();
    let input = random_input(&m, 11);
    let out = session.run(&input).unwrap();
    assert_eq!(out.output, golden_forward(&m, &input));
    // Half the cycles of the 2/2 configuration.
    let m22 = shrink(resnet9_cifar10(2, 2));
    let c22 = compile_pipelined(&m22, EdgePolicy::PadInRam).unwrap();
    assert_eq!(out.total_mvu_cycles * 2, c22.total_analytic_cycles());
}

/// The streamed-program acceptance test at ResNet-9 scale: the generated
/// multi-frame Pito program, executed natively on the cycle-accurate
/// backend (`StreamDriver::Program` — the cycle-accurate default), agrees
/// bit-for-bit with the host-driven lap replay and the golden reference on
/// every frame, per-layer cycle books included.
#[test]
fn resnet9_streamed_program_bit_exact() {
    use barvinn::session::StreamDriver;
    let m = model_under_test();
    let inputs: Vec<Tensor3> = (0..3).map(|s| random_input(&m, 500 + s)).collect();
    let mut run_with = |driver: StreamDriver| {
        let mut s = SessionBuilder::new(m.clone())
            .edge_policy(EdgePolicy::PadInRam)
            .exec_mode(ExecMode::CycleAccurate)
            .stream_driver(driver)
            .build()
            .unwrap();
        s.run_stream(&inputs).unwrap()
    };
    let prog = run_with(StreamDriver::Program);
    let host = run_with(StreamDriver::HostLaps);
    for (f, input) in inputs.iter().enumerate() {
        assert_eq!(
            prog.outputs[f].output,
            golden_forward(&m, input),
            "frame {f}: program-driven != golden"
        );
        assert_eq!(
            prog.outputs[f].output, host.outputs[f].output,
            "frame {f}: engines disagree"
        );
        assert_eq!(
            prog.outputs[f].mvu_cycles, host.outputs[f].mvu_cycles,
            "frame {f}: cycle books disagree"
        );
    }
    assert_eq!(prog.stream.frames, 3);
    assert_eq!(prog.stream.pipeline_cycles, host.stream.pipeline_cycles);
    assert_eq!(prog.stream.serial_cycles, host.stream.serial_cycles);
}
