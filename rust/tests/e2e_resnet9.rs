//! Integration: the full quantized ResNet9 through the pito-driven 8-MVU
//! pipeline at real 32×32 scale, verified bit-exactly against the Rust
//! golden model, plus Table-3 cycle accounting.
//!
//! Heavy paths are release-only (`make test` runs `cargo test --release`);
//! under debug they downscale to keep `cargo test` responsive.

use barvinn::accel::{System, SystemConfig, SystemExit};
use barvinn::codegen::{compile_pipelined, EdgePolicy};
use barvinn::model::zoo::{resnet9_cifar10, Rng};
use barvinn::model::Model;
use barvinn::quant::QuantSerCfg;
use barvinn::sim::{conv2d_i32, requant_i32, Tensor3};

fn golden_forward(model: &Model, input: &Tensor3) -> Tensor3 {
    let mut t = input.clone();
    for l in &model.layers {
        let acc = conv2d_i32(&t, &l.weights, l.spec());
        t = requant_i32(
            &acc,
            &l.quant.scale,
            &l.quant.bias,
            QuantSerCfg {
                msb_index: l.quant.quant_msb,
                out_bits: l.oprec.bits,
                saturate: true,
            },
            l.relu,
        );
    }
    t
}

fn model_under_test() -> Model {
    let mut m = resnet9_cifar10(2, 2);
    if cfg!(debug_assertions) {
        // Downscale spatially (keeps all 8 layers + channel widths).
        let mut h = 16;
        for l in &mut m.layers {
            l.in_h = h;
            l.in_w = h;
            if l.stride == 2 {
                h /= 2;
            }
        }
    }
    m.validate().unwrap();
    m
}

#[test]
fn pipelined_full_resnet9_bit_exact() {
    let m = model_under_test();
    let compiled = compile_pipelined(&m, EdgePolicy::PadInRam).unwrap();
    let mut sys = System::new(SystemConfig::default());
    let mut rng = Rng(2026);
    let l0 = &m.layers[0];
    let input = Tensor3::from_fn(l0.ci, l0.in_h, l0.in_w, |_, _, _| rng.range_i32(0, 3));
    compiled.load_into(&mut sys, &input);
    let exit = sys.run();
    assert_eq!(exit, SystemExit::AllExited, "{:?}", sys.launch_errors());
    let got = compiled.read_output(&sys, m.layers.last().unwrap().co);
    assert_eq!(got, golden_forward(&m, &input), "accelerator != golden");
    assert_eq!(sys.total_mvu_busy_cycles(), compiled.total_analytic_cycles());
}

#[test]
fn table3_cycles_full_scale() {
    // Analytic accounting at real scale is cheap in any build mode.
    let m = resnet9_cifar10(2, 2);
    let expected = [34560u64, 34560, 17280, 32256, 16128, 27648, 13824, 18432];
    for (l, &want) in m.layers.iter().zip(&expected) {
        assert_eq!(
            barvinn::codegen::layer_cycles(l, EdgePolicy::SkipEdges),
            want,
            "{}",
            l.name
        );
    }
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only (make test): full 32x32 measured run")]
fn table3_cycles_measured_full_scale() {
    let m = resnet9_cifar10(2, 2);
    let compiled = compile_pipelined(&m, EdgePolicy::SkipEdges).unwrap();
    let mut sys = System::new(SystemConfig::default());
    let mut rng = Rng(7);
    let input = Tensor3::from_fn(64, 32, 32, |_, _, _| rng.range_i32(0, 3));
    compiled.load_into(&mut sys, &input);
    assert_eq!(sys.run(), SystemExit::AllExited);
    let expected = [34560u64, 34560, 17280, 32256, 16128, 27648, 13824, 18432];
    for (h, &want) in expected.iter().enumerate() {
        assert_eq!(sys.mvus[h].busy_cycles(), want, "layer {h}");
    }
    assert_eq!(sys.total_mvu_busy_cycles(), 194_688, "Table 3 total");
}

#[test]
fn mixed_precision_pipeline() {
    // 1-bit weights / 2-bit activations end-to-end (precision is per-MVU
    // runtime state).
    let mut m = resnet9_cifar10(2, 1);
    let mut h = 8;
    for l in &mut m.layers {
        l.in_h = h;
        l.in_w = h;
        if l.stride == 2 {
            h /= 2;
        }
    }
    m.layers.truncate(5);
    m.validate().unwrap();
    let compiled = compile_pipelined(&m, EdgePolicy::PadInRam).unwrap();
    let mut sys = System::new(SystemConfig::default());
    let mut rng = Rng(11);
    let input = Tensor3::from_fn(64, 8, 8, |_, _, _| rng.range_i32(0, 3));
    compiled.load_into(&mut sys, &input);
    assert_eq!(sys.run(), SystemExit::AllExited);
    let got = compiled.read_output(&sys, m.layers.last().unwrap().co);
    assert_eq!(got, golden_forward(&m, &input));
    // Half the cycles of the 2/2 configuration.
    let m22 = {
        let mut m22 = resnet9_cifar10(2, 2);
        let mut h = 8;
        for l in &mut m22.layers {
            l.in_h = h;
            l.in_w = h;
            if l.stride == 2 {
                h /= 2;
            }
        }
        m22.layers.truncate(5);
        m22
    };
    let c22 = compile_pipelined(&m22, EdgePolicy::PadInRam).unwrap();
    assert_eq!(
        compiled.total_analytic_cycles() * 2,
        c22.total_analytic_cycles()
    );
}
