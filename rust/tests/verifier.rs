//! Static-verifier acceptance tests: the compiled zoo verifies clean at
//! every level, and each seeded fault class — corrupt address, misdirected
//! read, parity flip, dropped sync store, inflated tile count, invalid
//! job, undecodable word — is rejected **statically** with its stable
//! diagnostic code, before a single simulated cycle. Where the fault has a
//! crisp runtime symptom (panic, hang, cycle drift) the same mutation is
//! also driven through the simulator to show the verifier predicted it.

use barvinn::accel::{System, SystemConfig, SystemExit};
use barvinn::analysis::{
    verify_distributed, verify_multi_pass, verify_pipelined, DiagCode, VerifyLevel,
};
use barvinn::codegen::{compile_distributed, compile_multi_pass, compile_pipelined, EdgePolicy};
use barvinn::model::zoo::{self, Rng};
use barvinn::model::{ConvLayer, Model, QuantSpec};
use barvinn::mvu::MvuConfig;
use barvinn::pito::{decode, Instr, StoreOp};
use barvinn::quant::Precision;
use barvinn::sim::Tensor3;

const POLICY: EdgePolicy = EdgePolicy::PadInRam;

/// Small fixed two-layer 64-channel chain: fast to compile and simulate,
/// geometry-identical in kind to the zoo layers the verifier gates.
fn tiny_model() -> Model {
    let mut rng = Rng(0x7E57);
    let layer = |i: usize| ConvLayer {
        name: format!("tiny{i}"),
        ci: 64,
        co: 64,
        fh: 3,
        fw: 3,
        stride: 1,
        pad: 1,
        in_h: 4,
        in_w: 4,
        aprec: Precision::u(2),
        wprec: Precision::s(2),
        oprec: Precision::u(2),
        relu: false,
        weights: (0..64 * 64 * 9).map(|_| rng.range_i32(-2, 1)).collect(),
        quant: QuantSpec {
            scale: vec![1; 64],
            bias: vec![0; 64],
            quant_msb: 12,
        },
    };
    let m = Model {
        name: "tiny-chain".into(),
        layers: vec![layer(0), layer(1)],
        host_prologue: None,
        host_epilogue: None,
    };
    m.validate().expect("tiny model is well-formed");
    m
}

#[test]
fn zoo_models_verify_clean_at_every_level_and_mode() {
    let cfg = MvuConfig::default();
    // Pipelined resnet9 at the default 2-bit geometry.
    let m9 = zoo::model_by_name("resnet9", 2, 2).unwrap();
    let c = compile_pipelined(&m9, POLICY).unwrap();
    for level in [VerifyLevel::Quick, VerifyLevel::Full] {
        let r = verify_pipelined(&c, &m9, &cfg, level);
        assert!(r.is_clean(), "resnet9 pipelined {level:?}: {:?}", r.diagnostics);
        assert!(r.jobs_checked > 0, "jobs were actually walked");
        assert!(r.laps_checked > 0, "stream laps were actually checked");
        assert_eq!(r.harts_checked, barvinn::NUM_MVUS, "all harts walked");
    }
    // Off is a no-op gate.
    let off = verify_pipelined(&c, &m9, &cfg, VerifyLevel::Off);
    assert!(off.is_clean() && off.jobs_checked == 0);

    // Multi-pass resnet18 (16 layers → two pipelined passes).
    let m18 = zoo::model_by_name("resnet18", 2, 2).unwrap();
    let p = compile_multi_pass(&m18, POLICY).unwrap();
    let r = verify_multi_pass(&p, &m18, &cfg, VerifyLevel::Full);
    assert!(r.is_clean(), "resnet18 multipass: {:?}", r.diagnostics);

    // A distributed mapping of every resnet9 layer independently.
    for (h, layer) in m9.layers.iter().enumerate() {
        let d = compile_distributed(layer, POLICY).unwrap();
        let r = verify_distributed(&d, layer, &cfg, VerifyLevel::Full);
        assert!(r.is_clean(), "resnet9 layer {h} distributed: {:?}", r.diagnostics);
    }
}

#[test]
fn corrupt_address_is_rejected_statically_and_panics_at_runtime() {
    let m = tiny_model();
    let cfg = MvuConfig::default();
    let mut c = compile_pipelined(&m, POLICY).unwrap();
    c.plans[0].jobs[0].a_agu.base = 100_000; // far past act_depth = 32768
    let bad_job = c.plans[0].jobs[0].clone();
    let r = verify_pipelined(&c, &m, &cfg, VerifyLevel::Quick);
    assert!(r.has(DiagCode::AddrOob), "expected ADDR-OOB, got {:?}", r.diagnostics);

    // The same mutated job aborts the simulator (RAM index out of range) —
    // the class of failure the admission gate exists to rule out.
    let ran = std::panic::catch_unwind(|| {
        let mut sys = System::new(SystemConfig::default());
        sys.run_job(0, bad_job)
    });
    assert!(
        ran.is_err() || ran.unwrap().is_err(),
        "an out-of-bounds AGU walk must not complete cleanly"
    );
}

#[test]
fn misdirected_read_is_a_def_use_violation() {
    let m = tiny_model();
    let mut c = compile_pipelined(&m, POLICY).unwrap();
    // Shift every layer-0 activation read one whole buffer up: still inside
    // the RAM, but into words no producer of parity 0 ever wrote.
    let shift = c.plans[0].in_layout.size_words();
    for job in &mut c.plans[0].jobs {
        job.a_agu.base += shift;
    }
    let r = verify_pipelined(&c, &m, &MvuConfig::default(), VerifyLevel::Quick);
    assert!(r.has(DiagCode::DefUse), "expected DEF-USE, got {:?}", r.diagnostics);
}

#[test]
fn parity_flip_is_rejected() {
    let m = tiny_model();
    let mut c = compile_pipelined(&m, POLICY).unwrap();
    // Make the odd-parity twin alias the even buffers: frames i and i+1
    // would clobber each other in flight.
    c.stream_plans[0] = c.plans[0].clone();
    let r = verify_pipelined(&c, &m, &MvuConfig::default(), VerifyLevel::Quick);
    assert!(r.has(DiagCode::StreamParity), "expected STREAM-PARITY, got {:?}", r.diagnostics);
}

#[test]
fn dropped_sync_store_is_rejected_statically_and_hangs_at_runtime() {
    let m = tiny_model();
    let mut c = compile_pipelined(&m, POLICY).unwrap();
    // Drop every data-memory store: the inter-layer flag protocol's only
    // writes. Consumers' flag waits can then never be satisfied.
    for w in c.program.iter_mut() {
        if matches!(decode(*w), Ok(Instr::Store { op: StoreOp::Sw, .. })) {
            *w = 0x13; // addi x0, x0, 0
        }
    }
    let r = verify_pipelined(&c, &m, &MvuConfig::default(), VerifyLevel::Quick);
    assert!(r.has(DiagCode::SyncLiveness), "expected SYNC-LIVENESS, got {:?}", r.diagnostics);

    // Runtime ground truth: the consumer harts spin on flags nobody bumps
    // until the fuel runs out.
    let mut sys = System::new(SystemConfig::default());
    sys.load_program(&c.program);
    c.load_weights(&mut sys);
    let l0 = &m.layers[0];
    let input = Tensor3::from_fn(l0.ci, l0.in_h, l0.in_w, |_, _, _| 1);
    c.load_input(&mut sys, &input);
    sys.set_max_cycles(200_000);
    assert_eq!(sys.run(), SystemExit::MaxCycles, "dropped sync must hang, not finish");
}

#[test]
fn inflated_tiles_break_the_cycle_budget() {
    let m = tiny_model();
    let mut c = compile_pipelined(&m, POLICY).unwrap();
    let honest_cycles = c.plans[0].jobs[0].cycles();
    c.plans[0].jobs[0].tiles += 1;
    let inflated = c.plans[0].jobs[0].clone();
    let r = verify_pipelined(&c, &m, &MvuConfig::default(), VerifyLevel::Quick);
    assert!(r.has(DiagCode::CycleBudget), "expected CYCLE-BUDGET, got {:?}", r.diagnostics);

    // The simulator bills the inflated job differently than the plan's
    // analytic book — exactly the drift the static check forbids.
    let mut sys = System::new(SystemConfig::default());
    let measured = sys.run_job(0, inflated).unwrap();
    assert_ne!(measured, honest_cycles, "an inflated job cannot book honest cycles");
}

#[test]
fn invalid_job_and_undecodable_word_are_typed() {
    let m = tiny_model();
    let cfg = MvuConfig::default();

    let mut c = compile_pipelined(&m, POLICY).unwrap();
    c.plans[0].jobs[0].outputs = 0;
    let r = verify_pipelined(&c, &m, &cfg, VerifyLevel::Quick);
    assert!(r.has(DiagCode::JobInvalid), "expected JOB-INVALID, got {:?}", r.diagnostics);

    let mut c = compile_pipelined(&m, POLICY).unwrap();
    c.program[2] = 0xFFFF_FFFF; // no RV32I encoding
    let r = verify_pipelined(&c, &m, &cfg, VerifyLevel::Quick);
    assert!(r.has(DiagCode::ProgDecode), "expected PROG-DECODE, got {:?}", r.diagnostics);
}

#[test]
fn session_gate_is_on_by_default_and_tunable() {
    use barvinn::session::SessionBuilder;
    let m = tiny_model();
    // Default (Quick), explicit Full and explicit Off all admit a sound
    // plan; the rejection paths are exercised by the mutation tests above
    // against the same verifier the gate calls.
    for build in [
        SessionBuilder::new(m.clone()).edge_policy(POLICY).build(),
        SessionBuilder::new(m.clone()).edge_policy(POLICY).verify(VerifyLevel::Full).build(),
        SessionBuilder::new(m.clone()).edge_policy(POLICY).verify(VerifyLevel::Off).build(),
    ] {
        let mut session = build.expect("a sound plan passes the admission gate");
        let l0 = &m.layers[0];
        let input = Tensor3::from_fn(l0.ci, l0.in_h, l0.in_w, |_, _, _| 1);
        assert_eq!(session.run(&input).unwrap().output, m.golden_forward(&input));
    }
}

#[test]
fn json_report_follows_the_verify_v1_schema() {
    let m = tiny_model();
    let cfg = MvuConfig::default();

    let c = compile_pipelined(&m, POLICY).unwrap();
    // `to_json` emits compact JSON (no whitespace after separators).
    let clean = verify_pipelined(&c, &m, &cfg, VerifyLevel::Full).to_json();
    assert!(clean.contains("\"schema\":\"barvinn.verify/v1\""), "{clean}");
    assert!(clean.contains("\"clean\":true"), "{clean}");
    assert!(clean.contains("\"level\":\"full\""), "{clean}");

    let mut c = compile_pipelined(&m, POLICY).unwrap();
    c.plans[0].jobs[0].a_agu.base = 100_000;
    let dirty = verify_pipelined(&c, &m, &cfg, VerifyLevel::Quick).to_json();
    assert!(dirty.contains("\"clean\":false"), "{dirty}");
    assert!(dirty.contains("\"code\":\"ADDR-OOB\""), "{dirty}");
    assert!(dirty.contains("\"diagnostics\":["), "{dirty}");
}

/// The *streamed* multi-frame programs of the zoo verify clean: the
/// cross-frame flag protocol is proven live (with the host flags seeded at
/// their end-of-batch values) and every `START`'s snapshotted bases follow
/// the odd/even parity discipline, frame by frame, read off the
/// instruction stream itself.
#[test]
fn streamed_zoo_programs_verify_clean() {
    use barvinn::analysis::{verify_multi_pass_streamed, verify_streamed};
    let cfg = MvuConfig::default();
    for (wb, ab) in [(2u8, 2u8), (4, 4)] {
        let m9 = zoo::model_by_name("resnet9", ab, wb).unwrap();
        let c = compile_pipelined(&m9, POLICY).unwrap();
        let r = verify_streamed(&c, &m9, &cfg, 8, VerifyLevel::Quick);
        assert!(r.is_clean(), "resnet9 {wb}w/{ab}a streamed: {:?}", r.diagnostics);
        // The serial program and the multi-frame program are each walked.
        assert_eq!(r.harts_checked, 2 * barvinn::NUM_MVUS);
    }
    let m18 = zoo::model_by_name("resnet18", 2, 2).unwrap();
    let p = compile_multi_pass(&m18, POLICY).unwrap();
    let r = verify_multi_pass_streamed(&p, &m18, &cfg, 4, VerifyLevel::Quick);
    assert!(r.is_clean(), "resnet18 multipass streamed: {:?}", r.diagnostics);
    assert_eq!(r.harts_checked, 4 * barvinn::NUM_MVUS, "two passes, two walks each");
}

/// Fault injection on the streamed program *text*: each mutation patches
/// exactly one instruction of the generated assembly, reassembles, and
/// the verifier rejects the image with the stable code naming the broken
/// invariant — a dropped cross-frame bump is a liveness hole, a flattened
/// parity dispatch is a double-buffer violation.
#[test]
fn streamed_program_faults_are_typed() {
    use barvinn::analysis::verify_stream_program;
    use barvinn::pito::assemble;

    let m = tiny_model(); // two stages: hart 0 feeds hart 1
    let c = compile_pipelined(&m, POLICY).unwrap();
    let frames = 3; // >= 3 so the f-1 anti-dependence waits are non-trivial
    let sp = c.stream_program(frames).unwrap();

    // The unmutated image round-trips clean through the public seam.
    let r = verify_stream_program(&c, &sp.program, frames, VerifyLevel::Quick);
    assert!(r.is_clean(), "{:?}", r.diagnostics);

    // Patch the first (hart 0) or last (hart 1) occurrence of a marker.
    let mutate = |last: bool, from: &str, to: &str| -> Vec<u32> {
        let pos = if last { sp.asm.rfind(from) } else { sp.asm.find(from) }
            .unwrap_or_else(|| panic!("marker `{from}` not in the streamed program"));
        let mut patched = sp.asm.clone();
        patched.replace_range(pos..pos + from.len(), to);
        assert_ne!(patched, sp.asm);
        assemble(&patched).expect("mutated program still assembles")
    };

    // Nop hart 1's frame-retire bump: hart 0's anti-dependence wait on
    // FRAMES[1] >= f-1 can never be satisfied past the double buffer.
    let dropped_frame = mutate(true, "sw    s9, 0(t3)", "nop");
    let r = verify_stream_program(&c, &dropped_frame, frames, VerifyLevel::Quick);
    assert!(r.has(DiagCode::SyncLiveness), "expected SYNC-LIVENESS, got {:?}", r.diagnostics);

    // Nop hart 0's cumulative row bump: hart 1's first row wait spins on a
    // flag that plateaus at zero.
    let dropped_row = mutate(false, "sw    s11, 0(t3)", "nop");
    let r = verify_stream_program(&c, &dropped_row, frames, VerifyLevel::Quick);
    assert!(r.has(DiagCode::SyncLiveness), "expected SYNC-LIVENESS, got {:?}", r.diagnostics);

    // Flatten hart 0's parity dispatch: every frame launches the
    // even-parity bases — perfectly live, but frame 1's launches diverge
    // from the odd-parity plan.
    let flat_parity = mutate(false, "andi  t1, s9, 1", "li    t1, 0");
    let r = verify_stream_program(&c, &flat_parity, frames, VerifyLevel::Quick);
    assert!(r.has(DiagCode::StreamParity), "expected STREAM-PARITY, got {:?}", r.diagnostics);
}

/// Fault injection on *continuous admission*: a frame admitted into the
/// wrong parity buffer, a `HOST_IN` bump posted out of order, and
/// over-admission past the two-frame double buffer are each rejected
/// statically — no `System` is ever constructed, so not one cycle is
/// simulated — with the stable code naming the broken invariant.
#[test]
fn continuous_admission_faults_are_typed() {
    use barvinn::analysis::{verify_host_posting, verify_stream_program};
    use barvinn::pito::assemble;

    let m = tiny_model();
    let c = compile_pipelined(&m, POLICY).unwrap();
    let frames = 3;
    let sp = c.stream_program(frames).unwrap();

    // (a) Frame admitted with mismatched parity: pin hart 0's parity
    // dispatch to the *odd* twin, so the very first admitted frame lands
    // in buffers whose plan says frame 0 is even. Liveness is untouched;
    // the launch walk still catches the buffer swap.
    let pos = sp.asm.find("andi  t1, s9, 1").expect("parity dispatch marker");
    let mut patched = sp.asm.clone();
    patched.replace_range(pos..pos + "andi  t1, s9, 1".len(), "li    t1, 1");
    let odd_first = assemble(&patched).expect("mutated program still assembles");
    let r = verify_stream_program(&c, &odd_first, frames, VerifyLevel::Quick);
    assert!(r.has(DiagCode::StreamParity), "expected STREAM-PARITY, got {:?}", r.diagnostics);
    assert_eq!(DiagCode::StreamParity.as_str(), "STREAM-PARITY", "code is stable");

    // The canonical schedule — start the double buffer full, then one
    // admission per retirement — is clean for any feed length.
    for frames in [1usize, 2, 3, 8] {
        let posting: Vec<i32> = (frames.min(2) as i32..=frames as i32).collect();
        let r = verify_host_posting(frames, &posting, VerifyLevel::Full);
        assert!(r.is_clean(), "canonical posting for {frames} frames: {:?}", r.diagnostics);
    }

    // (b) HOST_IN bump posted out of order: the repost of 1 after 2 would
    // un-admit a frame hart 0 may already be fetching.
    let r = verify_host_posting(3, &[2, 1, 3], VerifyLevel::Quick);
    assert!(r.has(DiagCode::SyncLiveness), "expected SYNC-LIVENESS, got {:?}", r.diagnostics);
    assert_eq!(DiagCode::SyncLiveness.as_str(), "SYNC-LIVENESS", "code is stable");

    // (c) Over-admission past the two-frame buffer: a first post claiming
    // three staged frames, and a mid-stream jump of two, both stage a
    // frame into a parity buffer whose occupant cannot have retired.
    let r = verify_host_posting(4, &[3, 4], VerifyLevel::Quick);
    assert!(r.has(DiagCode::StreamParity), "expected STREAM-PARITY, got {:?}", r.diagnostics);
    let r = verify_host_posting(4, &[2, 4], VerifyLevel::Quick);
    assert!(r.has(DiagCode::StreamParity), "expected STREAM-PARITY, got {:?}", r.diagnostics);

    // Admitting past the end of the feed is the same class of fault.
    let r = verify_host_posting(2, &[2, 3], VerifyLevel::Quick);
    assert!(r.has(DiagCode::StreamParity), "expected STREAM-PARITY, got {:?}", r.diagnostics);

    // Under-admission starves hart 0's entry wait forever — a liveness
    // hole, whether the posting plateaus early or never happens at all.
    let r = verify_host_posting(4, &[2, 3], VerifyLevel::Quick);
    assert!(r.has(DiagCode::SyncLiveness), "expected SYNC-LIVENESS, got {:?}", r.diagnostics);
    let r = verify_host_posting(4, &[], VerifyLevel::Quick);
    assert!(r.has(DiagCode::SyncLiveness), "expected SYNC-LIVENESS, got {:?}", r.diagnostics);

    // `Off` is a no-op gate here exactly as it is for the plan walks.
    let off = verify_host_posting(3, &[2, 1, 3], VerifyLevel::Off);
    assert!(off.is_clean() && off.diagnostics.is_empty());
}
