//! Integration over the PJRT runtime + AOT artifacts: every HLO module
//! loads, executes and matches the Python-exported seams. Skips (with a
//! notice) when `make artifacts` has not run **or** when the crate was
//! built without the `pjrt` feature — `cargo test -q` stays green from a
//! fresh clone with no generated artifacts and no native XLA toolchain.

use barvinn::runtime::{ArtifactStore, Runtime};
use barvinn::session::SessionBuilder;

fn store() -> Option<ArtifactStore> {
    match ArtifactStore::open(None) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping runtime test: {e}");
            None
        }
    }
}

/// Artifacts + a live PJRT client, or `None` (with a notice) when either
/// is unavailable in this build/checkout.
fn ctx() -> Option<(ArtifactStore, Runtime)> {
    let store = store()?;
    match Runtime::cpu() {
        Ok(rt) => Some((store, rt)),
        Err(e) => {
            eprintln!("skipping runtime test: {e}");
            None
        }
    }
}

#[test]
fn conv0_artifact_matches_python_seam() {
    let Some((store, rt)) = ctx() else { return };
    let tv = store.test_vectors().unwrap();
    let conv0 = rt.load_hlo_text(&store.hlo_path("conv0")).unwrap();
    let q = conv0.run_f32_to_i32(&tv.image, &[1, 3, 32, 32]).unwrap();
    assert_eq!(q, tv.conv0_q);
    assert!(q.iter().all(|&v| (0..=3).contains(&v)), "2-bit codes");
}

#[test]
fn fc_artifact_produces_golden_logits() {
    let Some((store, rt)) = ctx() else { return };
    let tv = store.test_vectors().unwrap();
    let fc = rt.load_hlo_text(&store.hlo_path("fc")).unwrap();
    let logits = fc.run_i32_to_f32(&tv.final_acts, &[1, 512, 4, 4]).unwrap();
    assert_eq!(logits.len(), 10);
    for (a, b) in logits.iter().zip(&tv.golden_logits) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn golden_artifact_matches_python_logits() {
    let Some((store, rt)) = ctx() else { return };
    let tv = store.test_vectors().unwrap();
    let golden = rt.load_hlo_text(&store.hlo_path("golden")).unwrap();
    let logits = golden.run_f32(&tv.image, &[1, 3, 32, 32]).unwrap();
    for (a, b) in logits.iter().zip(&tv.golden_logits) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn bitserial_tile_artifact_equals_host_matmul() {
    let Some((store, rt)) = ctx() else { return };
    let tile = rt.load_hlo_text(&store.hlo_path("bitserial_tile")).unwrap();
    let mut rng = barvinn::model::zoo::Rng(13);
    let x: Vec<i32> = (0..64 * 576).map(|_| rng.range_i32(0, 3)).collect();
    let w: Vec<i32> = (0..576 * 64).map(|_| rng.range_i32(-2, 1)).collect();
    let out = tile.run_i32x2((&x, &[64, 576]), (&w, &[576, 64])).unwrap();
    // Full check against a host-side i64 matmul.
    for m in 0..64 {
        for n in 0..64 {
            let want: i64 =
                (0..576).map(|k| (x[m * 576 + k] * w[k * 64 + n]) as i64).sum();
            assert_eq!(out[m * 64 + n] as i64, want, "({m},{n})");
        }
    }
}

#[test]
fn model_json_loads_and_validates() {
    // Needs artifacts but not PJRT: the model graph is plain JSON.
    let Some(store) = store() else { return };
    if cfg!(debug_assertions) {
        eprintln!("skipping 12 MB JSON parse in debug build (run `make test`)");
        return;
    }
    let model = store.model().unwrap();
    assert_eq!(model.layers.len(), 8);
    assert_eq!(model.name, "resnet9-cifar10-w2a2");
    assert_eq!(model.host_prologue.as_deref(), Some("conv0"));
    // Table 3 cycles from the imported model too.
    let total: u64 = model
        .layers
        .iter()
        .map(|l| barvinn::codegen::layer_cycles(l, barvinn::codegen::EdgePolicy::SkipEdges))
        .sum();
    assert_eq!(total, 194_688);
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only (make test): full artifact e2e")]
fn full_e2e_python_seams_through_session() {
    // The same chain as examples/resnet9_e2e.rs, through the one-call
    // session facade: prologue → warm array → epilogue, twice.
    let Some((store, _rt)) = ctx() else { return };
    let tv = store.test_vectors().unwrap();
    let model = store.model().unwrap();
    let mut session = SessionBuilder::new(model)
        .artifacts(store)
        .build()
        .unwrap();
    let first = session.run_image(&tv.image).unwrap();
    assert_eq!(first.accel.output.data, tv.final_acts, "MVU array != python middle");
    for (a, b) in first.logits.iter().zip(&tv.golden_logits) {
        assert!((a - b).abs() < 1e-4);
    }
    // Warm reuse through the full host pipeline is deterministic.
    let second = session.run_image(&tv.image).unwrap();
    assert_eq!(first.logits, second.logits);
    assert_eq!(second.accel.image_index, 1);
}
