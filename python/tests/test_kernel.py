"""L1 kernel correctness: the Pallas bit-serial matmul (Alg. 1) and the
QuantSer kernel against their pure-jnp oracles, swept over shapes,
precisions and signedness with hypothesis."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitserial_matmul, quantser
from compile.kernels.ref import matmul_ref, quantser_ref


def rand_operand(rs, shape, bits, signed):
    if signed:
        lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    else:
        lo, hi = 0, (1 << bits) - 1
    return rs.randint(lo, hi + 1, size=shape).astype(np.int32)


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 24),
    k=st.integers(1, 48),
    n=st.integers(1, 24),
    a_bits=st.integers(1, 6),
    w_bits=st.integers(1, 6),
    a_signed=st.booleans(),
    w_signed=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_bitserial_matches_ref(m, k, n, a_bits, w_bits, a_signed, w_signed, seed):
    rs = np.random.RandomState(seed)
    x = rand_operand(rs, (m, k), a_bits, a_signed)
    w = rand_operand(rs, (k, n), w_bits, w_signed)
    got = bitserial_matmul(
        jnp.asarray(x),
        jnp.asarray(w),
        a_bits=a_bits,
        w_bits=w_bits,
        a_signed=a_signed,
        w_signed=w_signed,
    )
    want = matmul_ref(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block", [(32, 32), (64, 16), (16, 64)])
def test_bitserial_blocked_grid(block):
    rs = np.random.RandomState(3)
    x = rand_operand(rs, (64, 128), 2, False)
    w = rand_operand(rs, (128, 64), 2, True)
    got = bitserial_matmul(
        jnp.asarray(x), jnp.asarray(w), a_bits=2, w_bits=2, block=block
    )
    want = matmul_ref(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bitserial_mvu_tile_shape():
    """The exact tile the MVU consumes: 64 outputs × (64ch·3·3) patch."""
    rs = np.random.RandomState(9)
    x = rand_operand(rs, (64, 576), 2, False)
    w = rand_operand(rs, (576, 64), 2, True)
    got = bitserial_matmul(jnp.asarray(x), jnp.asarray(w), a_bits=2, w_bits=2)
    want = matmul_ref(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bitserial_extreme_precisions():
    rs = np.random.RandomState(5)
    for a_bits, w_bits in [(1, 8), (8, 1), (8, 8), (1, 1)]:
        x = rand_operand(rs, (8, 32), a_bits, False)
        w = rand_operand(rs, (32, 8), w_bits, True)
        got = bitserial_matmul(
            jnp.asarray(x), jnp.asarray(w), a_bits=a_bits, w_bits=w_bits
        )
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(matmul_ref(jnp.asarray(x), jnp.asarray(w)))
        )


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 128),
    msb=st.integers(2, 29),
    out_bits=st.integers(1, 8),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantser_matches_ref(n, msb, out_bits, relu, seed):
    if msb + 1 < out_bits:
        out_bits = msb + 1
    rs = np.random.RandomState(seed)
    v = rs.randint(-(1 << 20), 1 << 20, size=(n,)).astype(np.int32)
    s = rs.randint(1, 16, size=(n,)).astype(np.int32)
    b = rs.randint(-256, 256, size=(n,)).astype(np.int32)
    got = quantser(
        jnp.asarray(v), jnp.asarray(s), jnp.asarray(b),
        msb=msb, out_bits=out_bits, relu=relu,
    )
    want = quantser_ref(
        jnp.asarray(v), jnp.asarray(s), jnp.asarray(b), msb, out_bits, relu=relu
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_quantser_saturation_points():
    v = jnp.asarray(np.array([-5, 0, 63, 64, 191, 192, 1 << 20], np.int32))
    ones = jnp.ones(7, jnp.int32)
    zeros = jnp.zeros(7, jnp.int32)
    got = np.asarray(quantser(v, ones, zeros, msb=7, out_bits=2, relu=True))
    # window [7:6]: -5→0, 0→0, 63→0, 64→1, 191→2, 192→3, big→sat 3.
    np.testing.assert_array_equal(got, [0, 0, 0, 1, 2, 3, 3])
