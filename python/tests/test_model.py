"""L2 model correctness: Pallas path ≡ integer reference path, host/accel
seams compose to the golden model, shapes and determinism."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.model import (
    Resnet9Params,
    conv0_forward,
    fc_forward,
    golden_forward,
    make_params,
    middle_forward,
    middle_forward_pallas,
)


@pytest.fixture(scope="module")
def params() -> Resnet9Params:
    return make_params()


@pytest.fixture(scope="module")
def image():
    rs = np.random.RandomState(42)
    return jnp.asarray(rs.randn(1, 3, 32, 32).astype(np.float32))


def test_conv0_shape_and_range(params, image):
    q = conv0_forward(params, image)
    assert q.shape == (1, 64, 32, 32)
    assert q.dtype == jnp.int32
    qn = np.asarray(q)
    assert qn.min() >= 0 and qn.max() <= 3


def test_middle_shapes(params, image):
    q = conv0_forward(params, image)
    out = middle_forward(params, q)
    assert out.shape == (1, 512, 4, 4)
    on = np.asarray(out)
    assert on.min() >= 0 and on.max() <= 3


def test_composition_equals_golden(params, image):
    """conv0 → middle → fc must equal the single golden module — the same
    seam the Rust e2e example splits across PJRT + simulator."""
    q = conv0_forward(params, image)
    acts = middle_forward(params, q)
    logits = fc_forward(params, acts)
    golden = golden_forward(params, image)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(golden), rtol=1e-6)


def test_pallas_path_equals_reference(params, image):
    """Every conv through the L1 bit-serial kernel ≡ the integer reference.

    Run on a spatially-reduced copy to keep interpret-mode runtime sane."""
    small = make_params()
    h = 8
    for l in small.layers:
        l.in_h = l.in_w = h
        if l.stride == 2:
            h //= 2
    small.layers = small.layers[:4]
    rs = np.random.RandomState(0)
    q = jnp.asarray(rs.randint(0, 4, size=(1, 64, 8, 8)).astype(np.int32))
    ref_out = middle_forward(small, q)
    pallas_out = middle_forward_pallas(small, q)
    np.testing.assert_array_equal(np.asarray(ref_out), np.asarray(pallas_out))


def test_params_deterministic():
    a, b = make_params(), make_params()
    for la, lb in zip(a.layers, b.layers):
        np.testing.assert_array_equal(la.weights, lb.weights)
        np.testing.assert_array_equal(la.scale, lb.scale)
    np.testing.assert_array_equal(a.conv0_w, b.conv0_w)


def test_weight_ranges(params):
    for l in params.layers:
        assert l.weights.min() >= -2 and l.weights.max() <= 1
        assert l.scale.min() >= 1 and l.scale.max() <= 4


def test_no_accumulator_overflow(params):
    """The 32-bit pipeline must never overflow for any representable input:
    max |acc·scale + bias| bound."""
    for l in params.layers:
        ci = l.weights.shape[1]
        max_acc = ci * 9 * 3 * 2  # max act × max |weight|
        bound = max_acc * int(l.scale.max()) + int(np.abs(l.bias).max())
        assert bound < 2**31, l.name


def test_schedule_matches_table3_geometry():
    """The python schedule must be the Table 3 schedule."""
    total = 0
    for name, ci, co, stride, in_h in model.RESNET9_SCHEDULE:
        full_rows = (in_h - 3) // stride + 1
        out_w = (in_h + 2 - 3) // stride + 1
        cycles = 4 * (ci // 64) * 9 * (co // 64) * out_w * full_rows
        total += cycles
    assert total == 194_688
