"""AOT lowering: JAX → HLO **text** artifacts for the Rust PJRT runtime.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the crate's xla_extension
0.5.1 rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and DESIGN.md).

Artifacts written to ``--out`` (default ../artifacts):

* ``conv0.hlo.txt``   — host prologue: f32[1,3,32,32] → int32[1,64,32,32]
* ``fc.hlo.txt``      — host epilogue: int32[1,512,4,4] → f32[1,10]
* ``golden.hlo.txt``  — the whole network in one module (e2e oracle)
* ``bitserial_tile.hlo.txt`` — the L1 Pallas kernel on one 64×64×576 tile
  (interpret-mode lowering), so the Rust runtime exercises the kernel
* ``model.json``      — ONNX-lite graph for the code generator
* ``testvec.json``    — cross-language test vectors
* ``lsq_accuracy.json`` — Table 1/2 substitution demo results

Python runs ONCE at build time; nothing here is on the request path.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import export, model, quantize
from .kernels import bitserial_matmul


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default elides big
    # weight tensors as `constant({...})`, which the xla crate's HLO text
    # parser silently reads back as zeros.
    return comp.as_hlo_text(print_large_constants=True)


def lower_and_write(fn, args, path):
    text = to_hlo_text(jax.jit(fn).lower(*args))
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--lsq-steps", type=int, default=200)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    params = model.make_params()

    img_spec = jax.ShapeDtypeStruct((1, 3, 32, 32), jnp.float32)
    acts_spec = jax.ShapeDtypeStruct((1, 512, 4, 4), jnp.int32)

    print("lowering artifacts...")
    lower_and_write(
        functools.partial(model.conv0_forward, params),
        (img_spec,),
        os.path.join(args.out, "conv0.hlo.txt"),
    )
    lower_and_write(
        functools.partial(model.fc_forward, params),
        (acts_spec,),
        os.path.join(args.out, "fc.hlo.txt"),
    )
    lower_and_write(
        functools.partial(model.golden_forward, params),
        (img_spec,),
        os.path.join(args.out, "golden.hlo.txt"),
    )
    # The L1 kernel as its own artifact: one output row of a 64-channel conv
    # (64 pixels × 576 patch) — the tile shape the MVU consumes.
    lower_and_write(
        functools.partial(
            bitserial_matmul, a_bits=2, w_bits=2, a_signed=False, w_signed=True
        ),
        (
            jax.ShapeDtypeStruct((64, 576), jnp.int32),
            jax.ShapeDtypeStruct((576, 64), jnp.int32),
        ),
        os.path.join(args.out, "bitserial_tile.hlo.txt"),
    )

    # Model graph for the Rust code generator.
    export.write_json(export.model_to_json(params), os.path.join(args.out, "model.json"))
    print("  wrote model.json")

    # Cross-language test vectors.
    rs = np.random.RandomState(777)
    image = jnp.asarray(rs.randn(1, 3, 32, 32).astype(np.float32))
    conv0_q = model.conv0_forward(params, image)
    final_acts = model.middle_forward(params, conv0_q)
    logits = model.golden_forward(params, image)
    tv = export.testvec_to_json(image, conv0_q, final_acts, logits)
    tv["act_step"] = float(params.act_step)
    export.write_json(tv, os.path.join(args.out, "testvec.json"))
    print("  wrote testvec.json")

    # Table 1/2 substitution demo.
    quantize.main(os.path.join(args.out, "lsq_accuracy.json"), steps=args.lsq_steps)
    print("aot done.")


if __name__ == "__main__":
    main()
