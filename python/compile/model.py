"""L2: the quantized plain-CNN ResNet9 of §4.1 in JAX.

The network is split exactly the way the paper deploys it:

* ``conv0`` — first layer, kept in full precision and run on the host
  (AOT artifact ``conv0.hlo.txt``): fp32 conv + bias + ReLU, then LSQ
  quantization to the accelerator's activation precision.
* ``conv1..conv8`` — the 2-bit middle of the network, executed on the MVU
  array. Here they exist twice: an integer reference path (exact twin of
  the Rust golden model) and a Pallas path where each conv lowers to the
  bit-serial kernel via im2col — the two are asserted equal in pytest.
* ``fc`` — last layer on the host (artifact ``fc.hlo.txt``): dequantize,
  global average pool, fp32 linear head.

All integer arithmetic is int32 with wrapping semantics, matching the MVU
pipeline width, so the exported golden model is bit-identical to the Rust
simulator's output.
"""

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import bitserial_matmul
from .kernels.ref import conv2d_ref, quantser_ref

# The plain-CNN ResNet9 schedule reproducing Table 3 (name, ci, co, stride,
# in_h); all convs are 3×3, pad 1. Mirrors rust model::zoo::RESNET9_SCHEDULE.
RESNET9_SCHEDULE = [
    ("conv1", 64, 64, 1, 32),
    ("conv2", 64, 64, 1, 32),
    ("conv3", 64, 128, 2, 32),
    ("conv4", 128, 128, 1, 16),
    ("conv5", 128, 256, 2, 16),
    ("conv6", 256, 256, 1, 8),
    ("conv7", 256, 512, 2, 8),
    ("conv8", 512, 512, 1, 4),
]


@dataclasses.dataclass
class QuantLayer:
    """One accelerator conv layer (integer operands + folded requant)."""

    name: str
    weights: np.ndarray  # int32 [co, ci, 3, 3]
    scale: np.ndarray  # uint16 [co]
    bias: np.ndarray  # int32 [co]
    stride: int
    quant_msb: int
    a_bits: int = 2
    w_bits: int = 2
    o_bits: int = 2
    in_h: int = 32
    in_w: int = 32


@dataclasses.dataclass
class Resnet9Params:
    """Full model parameters."""

    conv0_w: np.ndarray  # f32 [64, 3, 3, 3]
    conv0_b: np.ndarray  # f32 [64]
    conv0_step: float  # LSQ step for the first quantization
    layers: List[QuantLayer]
    fc_w: np.ndarray  # f32 [512, 10]
    fc_b: np.ndarray  # f32 [10]
    act_step: float  # dequantization step feeding the head


def make_params(seed: int = 12345, a_bits: int = 2, w_bits: int = 2) -> Resnet9Params:
    """Deterministic synthetic parameters (training happens in
    ``quantize.train_lsq_demo``; the system-level artifacts need valid
    operands and exact cross-language reproducibility, not accuracy).

    The QuantSer window of each layer is *calibrated*: activations are
    propagated through the stack once and `quant_msb` is chosen from the
    99th percentile of the post-scaler values — the integer analogue of
    fitting the LSQ step — so codes use the full 2-bit space end-to-end
    instead of dying to zero under a worst-case bound."""
    rs = np.random.RandomState(seed)
    wmin, wmax = -(1 << (w_bits - 1)), (1 << (w_bits - 1)) - 1
    amax = (1 << a_bits) - 1
    layers = []
    # Calibration activations (kept off the exported test-vector seed).
    q = jnp.asarray(rs.randint(0, amax + 1, size=(1, 64, 32, 32)).astype(np.int32))
    for name, ci, co, stride, in_h in RESNET9_SCHEDULE:
        w = rs.randint(wmin, wmax + 1, size=(co, ci, 3, 3)).astype(np.int32)
        scale = rs.randint(1, 5, size=(co,)).astype(np.uint16)
        bias = rs.randint(-64, 65, size=(co,)).astype(np.int32)
        # Calibrate the window on the live activation distribution.
        acc = conv2d_ref(q, jnp.asarray(w), stride=stride, pad=1)
        y = jnp.maximum(
            acc * jnp.asarray(scale.astype(np.int32))[None, :, None, None]
            + jnp.asarray(bias)[None, :, None, None],
            0,
        )
        p99 = int(np.percentile(np.asarray(y), 99.0))
        msb = max(p99.bit_length() - 1, a_bits - 1)
        layer = QuantLayer(
            name=name,
            weights=w,
            scale=scale,
            bias=bias,
            stride=stride,
            quant_msb=msb,
            a_bits=a_bits,
            w_bits=w_bits,
            o_bits=a_bits,
            in_h=in_h,
            in_w=in_h,
        )
        layers.append(layer)
        q = quantser_ref(
            acc,
            jnp.asarray(scale.astype(np.int32))[None, :, None, None],
            jnp.asarray(bias)[None, :, None, None],
            msb,
            a_bits,
            relu=True,
        )
    return Resnet9Params(
        conv0_w=(rs.randn(64, 3, 3, 3) * 0.2).astype(np.float32),
        conv0_b=(rs.randn(64) * 0.1).astype(np.float32),
        conv0_step=0.5,
        layers=layers,
        fc_w=(rs.randn(512, 10) * 0.05).astype(np.float32),
        fc_b=np.zeros(10, dtype=np.float32),
        act_step=0.25,
    )


# --- host prologue: conv0 ----------------------------------------------------


def conv0_forward(params: Resnet9Params, image):
    """fp32 first layer + LSQ quantization to a_bits codes.

    image: f32 [1, 3, 32, 32] → int32 codes [1, 64, 32, 32].
    """
    y = jax.lax.conv_general_dilated(
        image,
        jnp.asarray(params.conv0_w),
        window_strides=(1, 1),
        padding=[(1, 1), (1, 1)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    y = y + jnp.asarray(params.conv0_b)[None, :, None, None]
    y = jnp.maximum(y, 0.0)
    amax = (1 << params.layers[0].a_bits) - 1
    q = jnp.clip(jnp.round(y / params.conv0_step), 0, amax)
    return q.astype(jnp.int32)


# --- accelerator middle: conv1..conv8 ---------------------------------------


def middle_forward(params: Resnet9Params, q):
    """Integer reference path: exact twin of the Rust golden model."""
    for l in params.layers:
        acc = conv2d_ref(q, jnp.asarray(l.weights), stride=l.stride, pad=1)
        q = quantser_ref(
            acc,
            jnp.asarray(l.scale.astype(np.int32))[None, :, None, None],
            jnp.asarray(l.bias)[None, :, None, None],
            l.quant_msb,
            l.o_bits,
            relu=True,
        )
    return q


def _conv_bitserial(q, layer: QuantLayer):
    """One conv via im2col + the Pallas bit-serial kernel (Alg. 1)."""
    n, ci, h, w = q.shape
    assert n == 1
    patches = jax.lax.conv_general_dilated_patches(
        q.astype(jnp.int32),
        filter_shape=(3, 3),
        window_strides=(layer.stride, layer.stride),
        padding=[(1, 1), (1, 1)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # [1, ci*9, oh, ow]
    _, ck, oh, ow = patches.shape
    x = patches.reshape(ck, oh * ow).T  # [oh*ow, ci*9]
    wmat = jnp.asarray(layer.weights).reshape(layer.weights.shape[0], ck).T
    acc = bitserial_matmul(
        x, wmat, a_bits=layer.a_bits, w_bits=layer.w_bits, a_signed=False, w_signed=True
    )  # [oh*ow, co]
    return acc.T.reshape(1, layer.weights.shape[0], oh, ow)


def middle_forward_pallas(params: Resnet9Params, q):
    """Same computation with every conv's accumulation running through the
    L1 Pallas kernel — the path asserted equal to `middle_forward`."""
    for l in params.layers:
        acc = _conv_bitserial(q, l)
        q = quantser_ref(
            acc,
            jnp.asarray(l.scale.astype(np.int32))[None, :, None, None],
            jnp.asarray(l.bias)[None, :, None, None],
            l.quant_msb,
            l.o_bits,
            relu=True,
        )
    return q


# --- host epilogue: fc -------------------------------------------------------


def fc_forward(params: Resnet9Params, q):
    """Dequantize, global average pool, fp32 linear head.

    q: int32 [1, 512, 4, 4] → logits f32 [1, 10].
    """
    x = q.astype(jnp.float32) * params.act_step
    x = x.mean(axis=(2, 3))  # [1, 512]
    return x @ jnp.asarray(params.fc_w) + jnp.asarray(params.fc_b)


# --- full golden model -------------------------------------------------------


def golden_forward(params: Resnet9Params, image):
    """image f32 [1,3,32,32] → logits f32 [1,10]; the single-HLO golden
    artifact the Rust e2e example checks against."""
    return fc_forward(params, middle_forward(params, conv0_forward(params, image)))
