"""Learned Step Size Quantization (LSQ, Esser et al. 2020) in JAX, plus the
integer folding that maps learned float parameters onto the MVU's
scaler/bias/QuantSer pipeline — the Python twin of ``rust/src/quant/lsq``.

Also hosts the Table 1/2 accuracy substitution experiment: the paper trains
ResNet18/CIFAR100 and ResNet9/CIFAR10 for days; here a small CNN is LSQ-
trained on a synthetic 10-class image problem for a few hundred steps to
demonstrate the *trend* (quantized ≈ fp32 accuracy at a fraction of the
size). See DESIGN.md §4 for the substitution rationale.
"""

import dataclasses
import functools
import json
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


# --- LSQ primitives ----------------------------------------------------------


def lsq_quantize(x, step, bits, signed=False):
    """LSQ fake-quantization with the straight-through gradient estimator.

    v = clamp(round(x/step), qmin, qmax) * step, with d(round)≈identity and
    the step gradient of the LSQ paper.
    """
    qmax = (1 << (bits - 1)) - 1 if signed else (1 << bits) - 1
    qmin = -(1 << (bits - 1)) if signed else 0

    @jax.custom_vjp
    def _q(x, step):
        v = jnp.clip(jnp.round(x / step), qmin, qmax)
        return v * step

    def _fwd(x, step):
        return _q(x, step), (x, step)

    def _bwd(res, g):
        x, step = res
        v = x / step
        inside = (v >= qmin) & (v <= qmax)
        # STE for x; LSQ gradient for step.
        gx = jnp.where(inside, g, 0.0)
        gs = jnp.where(
            inside,
            (jnp.round(v) - v) * g,
            jnp.where(v < qmin, qmin * g, qmax * g),
        )
        # Gradient scale 1/sqrt(N·qmax) per the paper.
        gscale = 1.0 / jnp.sqrt(jnp.maximum(qmax, 1) * x.size)
        return gx, jnp.sum(gs) * gscale

    _q.defvjp(_fwd, _bwd)
    return _q(x, step)


def fold_lsq(multiplier: float, offset: float, out_bits: int):
    """Fold a float requant multiplier/offset into the MVU integer pipeline:
    `(scale u16, bias i32, msb)` with `scale/2^f ≈ multiplier` — the exact
    algorithm of rust `quant::fold_lsq` (kept in sync by pytest).
    """
    assert multiplier > 0, "multiplier must be positive"
    best = None
    for f in range(0, 32 - out_bits):
        s = round(multiplier * (1 << f))
        if 1 <= s <= 0xFFFF:
            best = (f, s)
    if best is None:
        raise ValueError(f"multiplier {multiplier} not representable as u16/2^f")
    f, scale = best
    round_half = (1 << (f - 1)) if f > 0 else 0
    bias = int(round(offset * (1 << f))) + round_half
    assert -(2**31) <= bias < 2**31, "folded bias overflows i32"
    return scale, bias, f + out_bits - 1


# --- Table 1/2 substitution experiment ---------------------------------------


def _synthetic_images(rs: np.random.RandomState, n: int, classes: int = 10):
    """10-class synthetic image problem: class-dependent frequency patterns
    plus noise, 3×16×16 — small enough to train in seconds, hard enough
    that quantization effects are visible."""
    ys = rs.randint(0, classes, size=n)
    xx, yy = np.meshgrid(np.arange(16), np.arange(16))
    imgs = np.zeros((n, 3, 16, 16), np.float32)
    for i, y in enumerate(ys):
        fx, fy = 1 + y % 4, 1 + y // 4
        base = np.sin(2 * np.pi * fx * xx / 16) * np.cos(2 * np.pi * fy * yy / 16)
        for c in range(3):
            imgs[i, c] = base * (0.5 + 0.3 * c) + rs.randn(16, 16) * 1.1
    return imgs, ys


@dataclasses.dataclass
class LsqDemoResult:
    accuracy: Dict[str, float]
    size_bytes: Dict[str, int]


def train_lsq_demo(steps: int = 300, seed: int = 0) -> LsqDemoResult:
    """Train a small CNN at fp32 and LSQ 2/4/8-bit; report accuracy + size."""
    rs = np.random.RandomState(seed)
    xtr, ytr = _synthetic_images(rs, 2048)
    xte, yte = _synthetic_images(rs, 512)

    c1, c2, fc = 16, 32, 10

    def init():
        r = np.random.RandomState(seed + 1)
        return {
            "w1": jnp.asarray(r.randn(c1, 3, 3, 3).astype(np.float32) * 0.3),
            "w2": jnp.asarray(r.randn(c2, c1, 3, 3).astype(np.float32) * 0.15),
            "wf": jnp.asarray(r.randn(c2 * 4 * 4, fc).astype(np.float32) * 0.05),
            "s_w1": jnp.float32(0.1),
            "s_w2": jnp.float32(0.05),
            "s_a1": jnp.float32(0.5),
            "s_a2": jnp.float32(0.5),
        }

    def conv(x, w, stride):
        return jax.lax.conv_general_dilated(
            x, w, (stride, stride), [(1, 1), (1, 1)],
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        )

    def forward(p, x, bits):
        w1, w2 = p["w1"], p["w2"]
        if bits is not None:
            w1 = lsq_quantize(w1, p["s_w1"], bits, signed=True)
            w2 = lsq_quantize(w2, p["s_w2"], bits, signed=True)
        h = jax.nn.relu(conv(x, w1, 2))  # 16→8
        if bits is not None:
            h = lsq_quantize(h, p["s_a1"], bits, signed=False)
        h = jax.nn.relu(conv(h, w2, 2))  # 8→4
        if bits is not None:
            h = lsq_quantize(h, p["s_a2"], bits, signed=False)
        return h.reshape(h.shape[0], -1) @ p["wf"]

    def loss_fn(p, x, y, bits):
        logits = forward(p, x, bits)
        return -jnp.mean(
            jax.nn.log_softmax(logits)[jnp.arange(y.shape[0]), y]
        )

    @functools.partial(jax.jit, static_argnames=("bits_key",))
    def accuracy(p, bits_key):
        bits = {"fp32": None, "2": 2, "4": 4, "8": 8}[bits_key]
        preds = jnp.argmax(forward(p, jnp.asarray(xte), bits), axis=1)
        return jnp.mean(preds == jnp.asarray(yte))

    results, sizes = {}, {}
    n_params = int(c1 * 3 * 9 + c2 * c1 * 9 + c2 * 16 * fc)
    for key, bits in [("fp32", None), ("2", 2), ("4", 4), ("8", 8)]:
        p = init()
        grad = jax.jit(jax.grad(lambda p, x, y: loss_fn(p, x, y, bits)))
        lr = 0.05
        for step in range(steps):
            i = (step * 128) % (2048 - 128)
            g = grad(p, jnp.asarray(xtr[i : i + 128]), jnp.asarray(ytr[i : i + 128]))
            p = jax.tree.map(lambda a, b: a - lr * b, p, g)
        results[key] = float(accuracy(p, key))
        wbits = 32 if bits is None else bits
        # fc kept fp32 (the paper keeps first/last layers full precision).
        sizes[key] = (c1 * 27 + c2 * c1 * 9) * wbits // 8 + c2 * 16 * fc * 4
    _ = n_params
    return LsqDemoResult(accuracy=results, size_bytes=sizes)


def main(out_path: str = "../artifacts/lsq_accuracy.json", steps: int = 300):
    r = train_lsq_demo(steps=steps)
    with open(out_path, "w") as f:
        json.dump({"accuracy": r.accuracy, "size_bytes": r.size_bytes}, f, indent=1)
    print(f"lsq demo: {r.accuracy} → {out_path}")


if __name__ == "__main__":
    main()
