"""Export the quantized model to the ONNX-lite JSON the Rust code generator
ingests (§3.3: "The code generator exports weights to the bit-transposed
format" — bit-transposition itself happens in rust `codegen::layout`, from
the integer weights serialized here), plus cross-language test vectors.
"""

import json

import numpy as np

from .model import Resnet9Params


def model_to_json(params: Resnet9Params) -> dict:
    layers = []
    for l in params.layers:
        co, ci, fh, fw = l.weights.shape
        oh = (l.in_h + 2 - 3) // l.stride + 1
        layers.append(
            {
                "name": l.name,
                "ci": ci,
                "co": co,
                "fh": fh,
                "fw": fw,
                "stride": l.stride,
                "pad": 1,
                "in_h": l.in_h,
                "in_w": l.in_w,
                "aprec": {"bits": l.a_bits, "signed": False},
                "wprec": {"bits": l.w_bits, "signed": True},
                "oprec": {"bits": l.o_bits, "signed": False},
                "relu": True,
                "weights": l.weights.flatten().tolist(),
                "scale": l.scale.astype(np.int64).tolist(),
                "bias": l.bias.tolist(),
                "quant_msb": l.quant_msb,
            }
        )
        del oh
    return {
        "name": "resnet9-cifar10-w2a2",
        "host_prologue": "conv0",
        "host_epilogue": "fc",
        "layers": layers,
    }


def testvec_to_json(image, conv0_q, final_acts, logits) -> dict:
    """Cross-language vectors: the Rust e2e path checks each seam."""
    return {
        "image": np.asarray(image, dtype=np.float64).flatten().tolist(),
        "image_shape": list(np.asarray(image).shape),
        "conv0_q": np.asarray(conv0_q).flatten().astype(int).tolist(),
        "conv0_q_shape": list(np.asarray(conv0_q).shape),
        "final_acts": np.asarray(final_acts).flatten().astype(int).tolist(),
        "final_acts_shape": list(np.asarray(final_acts).shape),
        "golden_logits": np.asarray(logits, dtype=np.float64).flatten().tolist(),
        "act_step": None,  # filled by aot.py
    }


def write_json(obj: dict, path: str):
    with open(path, "w") as f:
        json.dump(obj, f, separators=(",", ":"))
