"""L1: Pallas kernels for the paper's compute hot-spot (Alg. 1) and the
post-MVP requantization stage, plus their pure-jnp oracles."""

from .bitserial import bitserial_matmul, vmem_bytes
from .quantser import quantser
from . import ref

__all__ = ["bitserial_matmul", "vmem_bytes", "quantser", "ref"]
