"""L1 Pallas kernel: the scaler → bias → ReLU → quantizer/serializer
pipeline stage (§3.1.4), mirroring `rust/src/mvu/{scaler,quantser}`.

Elementwise over the 64-lane output vectors: multiply by the per-channel
16-bit scaler operand, add the 32-bit bias, ReLU through the comparator,
then select `out_bits` bits below `msb` with saturation — the integer form
into which LSQ requantization folds (quant::lsq on the Rust side).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantser_kernel(v_ref, s_ref, b_ref, o_ref, *, msb, out_bits, relu):
    v = v_ref[...].astype(jnp.int32)
    s = s_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    y = v * s + b
    if relu:
        y = jnp.maximum(y, 0)
    shift = msb + 1 - out_bits
    max_code = (1 << out_bits) - 1
    sel = jnp.right_shift(y, shift) & max_code
    if msb < 30:
        # For msb >= 30 no int32 value can exceed the window: no clamp,
        # matching quant::quantser on the Rust side.
        sel = jnp.where(y >= jnp.int32(1 << (msb + 1)), max_code, sel)
    sel = jnp.where(y < 0, 0, sel)
    o_ref[...] = sel.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("msb", "out_bits", "relu"))
def quantser(v, scale, bias, *, msb, out_bits, relu=True):
    """Requantize accumulators `v` [..., C] with per-channel `scale`/`bias`
    (broadcast over leading dims)."""
    s = jnp.broadcast_to(scale.astype(jnp.int32), v.shape)
    b = jnp.broadcast_to(bias.astype(jnp.int32), v.shape)
    kern = functools.partial(_quantser_kernel, msb=msb, out_bits=out_bits, relu=relu)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(v.shape, jnp.int32),
        interpret=True,
    )(v.astype(jnp.int32), s, b)
