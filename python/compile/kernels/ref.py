"""Pure-jnp correctness oracles for the Pallas kernels.

These are the simplest possible statements of the math the MVU datapath
implements; every kernel (and, through the exported HLO artifacts, the Rust
simulator) is validated against them.
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    """Plain integer matmul: the value a bit-serial dot product must equal.

    x: [M, K] int32, w: [K, N] int32 -> [M, N] int32.
    """
    return jnp.dot(
        x.astype(jnp.int32), w.astype(jnp.int32), preferred_element_type=jnp.int32
    )


def quantser_ref(v, scale, bias, msb, out_bits, relu=True):
    """The MVU's post-MVP pipeline (3.1.4), exactly as the Rust model:

    y = v*scale + bias (int32, wrapping); optional ReLU;
    QuantSer with saturation: select bits [msb : msb-out_bits+1],
    clamping negatives to 0 and overflows to the max code.
    """
    v = v.astype(jnp.int32)
    y = v * scale.astype(jnp.int32) + bias.astype(jnp.int32)
    if relu:
        y = jnp.maximum(y, 0)
    shift = msb + 1 - out_bits
    max_code = (1 << out_bits) - 1
    sel = jnp.right_shift(y, shift) & max_code
    if msb < 30:
        sel = jnp.where(y >= jnp.int32(1 << (msb + 1)), max_code, sel)
    sel = jnp.where(y < 0, 0, sel)
    return sel.astype(jnp.int32)


def conv2d_ref(x, w, stride=1, pad=1):
    """Golden integer conv2d (NCHW x OIHW -> NCHW), int32 accumulation."""
    import jax.lax as lax

    return lax.conv_general_dilated(
        x.astype(jnp.int32),
        w.astype(jnp.int32),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        preferred_element_type=jnp.int32,
    )
