"""L1 Pallas kernel: the bit-serial matrix multiply of Algorithm 1.

The MVU's MVP computes `x · w` by decomposing both operands into bit planes
and accumulating the partial popcount-products in descending order of
magnitude through a shift-accumulator. This kernel is the same computation
expressed for the MXU: each (j, k) bit-plane pair becomes a 1-bit × 1-bit
matmul (lowered to the systolic array as an int32 matmul of 0/1 matrices),
and the shift-accumulator becomes a doubling of the accumulator between
magnitude levels — numerically *identical* to the FPGA datapath, which is
what lets the pytest suite cross-validate the Rust simulator against the
same oracle.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the FPGA tiles
64 (lanes) × 64 (VVPs); here the BlockSpec tiles (bm × bn) output blocks
with the full K dimension resident, sized so x-tile + w-tile + acc fit in
VMEM. Pallas runs with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls; real-TPU efficiency is estimated statically.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _plane_sign(bits: int, signed: bool, j: int) -> int:
    """Sign of bit-plane j: the two's-complement sign plane contributes
    negatively (Alg. 1 extended to signed operands)."""
    return -1 if (signed and j == bits - 1) else 1


def _bitserial_kernel(x_ref, w_ref, o_ref, *, a_bits, w_bits, a_signed, w_signed):
    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    m, _ = x.shape
    _, n = w.shape
    acc = jnp.zeros((m, n), jnp.int32)
    top = (a_bits - 1) + (w_bits - 1)
    # Descending order of magnitude; shift (double) between levels.
    for i in range(top, -1, -1):
        if i != top:
            acc = acc * 2
        for j in range(a_bits):
            k = i - j
            if k < 0 or k >= w_bits:
                continue
            # Bit j of two's complement survives arithmetic shift + mask.
            xj = jnp.right_shift(x, j) & 1
            wk = jnp.right_shift(w, k) & 1
            sign = _plane_sign(a_bits, a_signed, j) * _plane_sign(w_bits, w_signed, k)
            acc = acc + sign * jnp.dot(xj, wk, preferred_element_type=jnp.int32)
    o_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("a_bits", "w_bits", "a_signed", "w_signed", "block")
)
def bitserial_matmul(x, w, *, a_bits, w_bits, a_signed=False, w_signed=True, block=None):
    """Bit-serial `x @ w` on int32 operands holding `a_bits`/`w_bits`-bit
    values. Exact: equals `ref.matmul_ref(x, w)` for in-range operands.

    `block`: optional (bm, bn) output tile; defaults to the whole output
    (single program) which is right for the 64-aligned tiles the MVU uses.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} vs {k2}"
    kern = functools.partial(
        _bitserial_kernel,
        a_bits=a_bits,
        w_bits=w_bits,
        a_signed=a_signed,
        w_signed=w_signed,
    )
    if block is None:
        return pl.pallas_call(
            kern,
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
            interpret=True,
        )(x.astype(jnp.int32), w.astype(jnp.int32))
    bm, bn = block
    assert m % bm == 0 and n % bn == 0, "block must tile the output"
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(x.astype(jnp.int32), w.astype(jnp.int32))


def vmem_bytes(bm: int, bn: int, k: int) -> int:
    """Static VMEM footprint estimate of one grid step (int32 operands):
    x-tile + w-tile + acc. Used by the §Perf notes to pick block shapes
    under the ~16 MiB VMEM budget of a TPU core."""
    return 4 * (bm * k + k * bn + bm * bn)
