#!/usr/bin/env sh
# Freshness gate for the committed Pito program listings in docs/listings/.
#
# Each listing is the verbatim stdout of a `barvinn disasm --model ...`
# invocation; the generators are deterministic, so a byte-for-byte diff
# is exact. Modes:
#
#   tools/check-listings.sh            compare committed vs regenerated;
#                                      fail on drift (stale listing)
#   tools/check-listings.sh --update   regenerate the listings in place
#                                      (run after changing the emitters,
#                                      then commit the result)
#
# A listing that has never been committed is *seeded* in place and
# reported — commit the generated file to arm the gate for it. Set
# BARVINN_BIN to skip the cargo build (CI reuses the release binary).
# Run from the repo root. POSIX sh + cmp only.
set -u

update=0
[ "${1:-}" = "--update" ] && update=1

bin=${BARVINN_BIN:-}
if [ -z "$bin" ]; then
    cargo build --release --quiet || exit 1
    bin=target/release/barvinn
fi
if [ ! -x "$bin" ]; then
    echo "check-listings: barvinn binary not found at $bin" >&2
    exit 1
fi

mkdir -p docs/listings
tmp=$(mktemp)
fail=0
seeded=0
trap 'rm -f "$tmp"' EXIT

# listing file | disasm arguments
set -- \
    "resnet9_serial.s|--model resnet9 --wbits 2 --abits 2" \
    "resnet9_stream.s|--model resnet9 --wbits 2 --abits 2 --stream --frames 8"

for spec in "$@"; do
    file=docs/listings/${spec%%|*}
    args=${spec#*|}
    # shellcheck disable=SC2086 # word-splitting the argument list is intended
    if ! "$bin" disasm $args >"$tmp"; then
        echo "check-listings: \`barvinn disasm $args\` failed" >&2
        fail=1
        continue
    fi
    if [ "$update" = 1 ] || [ ! -f "$file" ]; then
        cp "$tmp" "$file"
        if [ "$update" = 1 ]; then
            echo "check-listings: regenerated $file"
        else
            echo "check-listings: seeded $file — commit it to arm the freshness gate" >&2
            seeded=1
        fi
        continue
    fi
    if ! cmp -s "$file" "$tmp"; then
        echo "check-listings: $file is stale (emitters changed?)" >&2
        diff "$file" "$tmp" | head -20 >&2
        echo "check-listings: run \`tools/check-listings.sh --update\` and commit" >&2
        fail=1
    fi
done

[ "$fail" = 1 ] && exit 1
if [ "$seeded" = 1 ]; then
    echo "listings: SEEDED (new files written; commit them)"
    exit 0
fi
echo "listings: OK"
