#!/usr/bin/env sh
# Fail on dangling *relative* markdown links in README.md and docs/*.md.
# Deliberately dependency-free (POSIX sh + grep/sed) so CI needs nothing
# beyond a checkout; run from the repo root.
#
# Checked: inline links/images `[text](target)` whose target is not an
# absolute URL or a pure fragment. Optional markdown titles
# (`[x](path "Title")`) and fragments (`docs/FOO.md#sec`) are stripped
# before the existence check. Targets are read line-wise, so paths with
# spaces are handled.
set -u

tmp=$(mktemp)
trap 'rm -f "$tmp"' EXIT

for f in README.md docs/*.md; do
    [ -f "$f" ] || continue
    dir=$(dirname "$f")
    grep -oE '\]\([^)]+\)' "$f" | sed -E 's/^\]\(//; s/\)$//' |
        while IFS= read -r link; do
            case "$link" in
            http://* | https://* | mailto:*) continue ;;
            '#'*) continue ;;
            esac
            target=${link%% \"*}
            target=${target%%#*}
            [ -n "$target" ] || continue
            if [ ! -e "$dir/$target" ]; then
                echo "dangling link in $f: $link" >&2
                echo fail >>"$tmp"
            fi
        done
done

if [ -s "$tmp" ]; then
    echo "docs-links: FAILED (fix the targets above or update the link)" >&2
    exit 1
fi
echo "docs-links: OK"
