#!/bin/sh
# Compare the deterministic fields of a fresh bench report against a
# committed snapshot (bench/*.json).
#
# Subset semantics: only keys present in the snapshot are compared — the
# snapshots deliberately omit every wall-clock- or thread-timing-dependent
# field (wall_s, p99_ms, cache_hits, sim_cycles, ...), keeping exactly the
# fields a fixed seed pins (see bench/README.md). Arrays of objects that
# carry a "key" field (per_key) are matched by key, not position: the
# metrics snapshot does not guarantee per-key ordering. Arrays of objects
# that carry a "pr" field (bench_trajectory/v1 entries) are matched by pr
# the same way — the fresh file may *append* entries (the current run's
# measurement) but never rewrite or drop a committed one.
#
# Usage: sh tools/bench-snapshot-diff.sh <committed-snapshot.json> <fresh-report.json>
set -eu

if [ "$#" -ne 2 ]; then
    echo "usage: $0 <committed-snapshot.json> <fresh-report.json>" >&2
    exit 2
fi
snap=$1
fresh=$2

SUBSET='
def subset($a; $b):
  ($a | type) as $t
  | if $t == "object" then
      ($b | type) == "object"
      and (($a | keys_unsorted)
           | all(. as $k | ($b | has($k)) and subset($a[$k]; $b[$k])))
    elif $t == "array" then
      ($b | type) == "array"
      and (if ($a | length) == 0 then true
           elif ($a[0] | type) == "object" and ($a[0] | has("key")) then
             $a | all(. as $e
               | ($b | map(select(.key == $e.key))) as $m
               | ($m | length) == 1 and subset($e; $m[0]))
           elif ($a[0] | type) == "object" and ($a[0] | has("pr")) then
             $a | all(. as $e
               | ($b | map(select(.pr == $e.pr))) as $m
               | ($m | length) == 1 and subset($e; $m[0]))
           else
             ($a | length) == ($b | length)
             and ([range($a | length)] | all(. as $i | subset($a[$i]; $b[$i])))
           end)
    else
      $a == $b
    end;
'

if jq -e -n --slurpfile want "$snap" --slurpfile got "$fresh" \
    "$SUBSET subset(\$want[0]; \$got[0])" >/dev/null; then
    echo "OK: $fresh matches every deterministic field of $snap"
else
    echo "MISMATCH: $fresh diverges from the committed snapshot $snap" >&2
    echo "--- committed deterministic fields ($snap):" >&2
    cat "$snap" >&2
    echo "--- fresh report ($fresh):" >&2
    cat "$fresh" >&2
    echo "A legitimate behaviour change must update the snapshot in the same PR" >&2
    echo "(see bench/README.md for what belongs in it)." >&2
    exit 1
fi
